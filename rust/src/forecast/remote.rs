//! Cross-machine shard transport: the [`ShardClient`] dispatch trait,
//! the [`RemoteShard`] HTTP proxy, and the hedged-read machinery the
//! ring uses to keep one slow replica from becoming a p99 cliff.
//!
//! Three pieces:
//!
//! * [`ShardClient`] — what the consistent-hash ring actually routes
//!   to. The in-process [`ServingStack`] implements it by plain
//!   forwarding; [`RemoteShard`] implements it by speaking the existing
//!   `/v1` wire format over a keep-alive
//!   [`ClientPool`](super::http::ClientPool), with per-request
//!   connect/read deadlines so a dead peer costs a bounded timeout, not
//!   a hang. The ring cannot tell the two apart — which is the point:
//!   every later scale-out (GPU shards behind a remote, M4-scale state)
//!   slots in behind this trait.
//! * [`RemoteShard`]'s background prober — one thread per remote,
//!   probing `GET /v1/healthz` on a short deadline. After
//!   `eject_after` consecutive failures the shard's `healthy` flag
//!   drops and the router stops *preferring* it; after `readmit_after`
//!   consecutive successes (probation) the flag restores. Ejection is
//!   a routing mask, never a ring mutation: the shard keeps its ring
//!   points, so readmission restores the exact pre-ejection placement
//!   and no keys move in either direction.
//! * [`HedgeClock`] + [`hedged_forecast`] — replicated reads. The
//!   primary replica is fired immediately; a timer starts at the
//!   rolling p95 of recent forecast latencies; on expiry the next
//!   replica is fired too and the first non-error response wins. The
//!   loser's thread drains its response and discards it (its channel
//!   send fails silently). A primary that fails *fast* (connection
//!   refused, queue full) fails over to the next replica immediately —
//!   that is failover, not a hedge, and is not counted as one.
//!
//! Instrumented through the PR 8 registry: per-remote
//! `fesrnn_remote_{inflight,request_seconds,probe_failures_total,
//! ejections_total}` under `{shard, addr}` labels (unregistered with
//! the shard's whole slice on removal), plus ring-level
//! `fesrnn_remote_{hedges,hedge_wins}_total`.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::Frequency;
use crate::coordinator::ModelState;
use crate::telemetry::registry::{Counter, Gauge, Histogram, Registry};
use crate::telemetry::Quantiles;
use crate::util::json::Json;

use super::api;
use super::api::{StaleObservation, UnknownSeries};
use super::http::{ClientOptions, ClientPool, HttpClient, HttpReply};
use super::pool::{ObserveOutcome, QueueFull};
use super::router::ServingStack;
use super::state::SeriesRecord;
use super::{ForecastRequest, ForecastResponse, ResponseReceiver,
            ServiceStats};
use crate::hw::EsState;

/// What the consistent-hash ring routes to: one shard's worth of
/// serving capacity, local or remote. Every method is the shard-shaped
/// subset of [`ServingStack`]'s API; [`RemoteShard`] adds health.
pub trait ShardClient: Send + Sync {
    /// Blocking forecast, dispatched by frequency inside the shard.
    fn forecast(&self, freq: Frequency, req: ForecastRequest)
                -> Result<ForecastResponse>;

    /// Non-blocking submit. A remote shard executes synchronously and
    /// delivers through a pre-filled channel; backpressure
    /// ([`QueueFull`]) still surfaces synchronously, matching the
    /// local pool's contract.
    fn submit(&self, freq: Frequency, req: ForecastRequest)
              -> Result<ResponseReceiver>;

    /// Advance one series' ES state on new observations (the stateful
    /// serving path). Defaulted to an error so special-purpose clients
    /// (test stubs, bench shims) that never see stateful traffic need
    /// not implement it.
    fn observe(&self, _freq: Frequency, id: &str, _values: &[f32],
               _t0: Option<u64>) -> Result<ObserveOutcome> {
        bail!("this shard client does not serve observes (series `{id}`)")
    }

    /// Stateful forecast from a series' stored ES state. Defaulted like
    /// [`observe`](Self::observe).
    fn series_forecast(&self, _freq: Frequency, id: &str)
                       -> Result<ForecastResponse> {
        bail!("this shard client does not serve stateful forecasts \
               (series `{id}`)")
    }

    /// The stored state record for one series. Defaulted like
    /// [`observe`](Self::observe).
    fn series_record(&self, _freq: Frequency, id: &str)
                     -> Result<SeriesRecord> {
        bail!("this shard client does not serve series state \
               (series `{id}`)")
    }

    /// Per-frequency serving stats (a remote's own aggregate).
    fn stats_snapshot(&self) -> Result<BTreeMap<Frequency, ServiceStats>>;

    /// Hot-swap `freq`'s model from an in-memory state. Remote shards
    /// refuse this (a `ModelState` is not wire-shippable) — use
    /// [`reload_checkpoint`](Self::reload_checkpoint), whose path is
    /// resolved on the shard's own filesystem.
    fn reload(&self, freq: Frequency, state: ModelState) -> Result<u64>;

    /// Hot-swap from a checkpoint path resolved *on the shard* (local:
    /// this process; remote: the remote server via `POST /v1/reload`).
    fn reload_checkpoint(&self, freq: Frequency, path: &Path) -> Result<u64>;

    /// Newest generation serving `freq`.
    fn generation(&self, freq: Frequency) -> Result<u64>;

    /// Frequencies this shard serves (ring invariant: identical on
    /// every member).
    fn frequencies(&self) -> Vec<Frequency>;

    /// The equalized history length required of requests for `freq`.
    fn required_length(&self, freq: Frequency) -> Result<usize>;

    /// Liveness check (remote: one `GET /v1/healthz` round-trip).
    fn healthz(&self) -> Result<()>;

    /// Routing mask: `false` while the prober has the shard ejected.
    /// Local shards are always healthy (their failures are synchronous
    /// errors, not silence).
    fn healthy(&self) -> bool {
        true
    }

    /// Health summary for `/v1/stats` and `fast-esrnn top`.
    fn health(&self) -> ShardHealth;

    /// Bind this shard's instruments into `reg` under a `shard` label
    /// (plus `addr` for remotes) as it joins a ring.
    fn bind_metrics(&self, reg: &Registry, shard: &str);
}

/// One shard's health row in `/v1/stats` (`"remote"."shards"`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardHealth {
    /// `"local"` (in-process [`ServingStack`]) or `"remote"`.
    pub kind: &'static str,
    /// Peer address, remotes only.
    pub addr: Option<String>,
    /// `false` while ejected by the prober.
    pub healthy: bool,
    /// Cumulative failed probes (a flapping peer shows up here long
    /// before it trips a full ejection).
    pub probe_failures: u64,
    /// Healthy→ejected transitions (counted once per transition).
    pub ejections: u64,
}

impl ShardHealth {
    fn local() -> Self {
        Self {
            kind: "local",
            addr: None,
            healthy: true,
            probe_failures: 0,
            ejections: 0,
        }
    }
}

impl ShardClient for ServingStack {
    fn forecast(&self, freq: Frequency, req: ForecastRequest)
                -> Result<ForecastResponse> {
        ServingStack::forecast(self, freq, req)
    }

    fn submit(&self, freq: Frequency, req: ForecastRequest)
              -> Result<ResponseReceiver> {
        ServingStack::submit(self, freq, req)
    }

    fn observe(&self, freq: Frequency, id: &str, values: &[f32],
               t0: Option<u64>) -> Result<ObserveOutcome> {
        ServingStack::observe(self, freq, id, values, t0)
    }

    fn series_forecast(&self, freq: Frequency, id: &str)
                       -> Result<ForecastResponse> {
        ServingStack::series_forecast(self, freq, id)
    }

    fn series_record(&self, freq: Frequency, id: &str)
                     -> Result<SeriesRecord> {
        ServingStack::series_record(self, freq, id)
    }

    fn stats_snapshot(&self) -> Result<BTreeMap<Frequency, ServiceStats>> {
        Ok(ServingStack::stats_all(self))
    }

    fn reload(&self, freq: Frequency, state: ModelState) -> Result<u64> {
        ServingStack::reload(self, freq, state)
    }

    fn reload_checkpoint(&self, freq: Frequency, path: &Path) -> Result<u64> {
        ServingStack::reload_checkpoint(self, freq, path)
    }

    fn generation(&self, freq: Frequency) -> Result<u64> {
        ServingStack::generation(self, freq)
    }

    fn frequencies(&self) -> Vec<Frequency> {
        ServingStack::frequencies(self)
    }

    fn required_length(&self, freq: Frequency) -> Result<usize> {
        ServingStack::required_length(self, freq)
    }

    fn healthz(&self) -> Result<()> {
        if ServingStack::is_empty(self) {
            bail!("no pools are running");
        }
        Ok(())
    }

    fn health(&self) -> ShardHealth {
        ShardHealth::local()
    }

    fn bind_metrics(&self, reg: &Registry, shard: &str) {
        ServingStack::bind_metrics(self, reg, shard);
    }
}

/// Knobs for one remote shard. The defaults suit a LAN peer; the
/// distributed integration test tightens the probe knobs to make
/// ejection observable in test time.
#[derive(Debug, Clone)]
pub struct RemoteOptions {
    /// TCP dial deadline for request connections.
    pub connect_timeout: Duration,
    /// Per-request read deadline — a dead peer costs this, not a hang.
    pub read_timeout: Duration,
    /// Keep-alive connections retained for reuse (concurrency above
    /// this dials extra connections that are dropped when idle).
    pub pool_size: usize,
    /// Pause between health probes.
    pub probe_interval: Duration,
    /// Dial+read deadline for one probe (deliberately tighter than the
    /// request deadlines: probes exist to notice silence quickly).
    pub probe_timeout: Duration,
    /// Consecutive probe failures before ejection.
    pub eject_after: u32,
    /// Consecutive probe successes before an ejected shard is
    /// readmitted (probation — one lucky probe must not readmit a
    /// flapping peer).
    pub readmit_after: u32,
}

impl Default for RemoteOptions {
    fn default() -> Self {
        Self {
            connect_timeout: Duration::from_secs(2),
            read_timeout: Duration::from_secs(10),
            pool_size: 4,
            probe_interval: Duration::from_secs(1),
            probe_timeout: Duration::from_secs(1),
            eject_after: 3,
            readmit_after: 2,
        }
    }
}

/// Health state shared between a [`RemoteShard`] and its prober
/// thread. The counters are the registry instruments themselves
/// (clones share the cell), so the prober increments what `/v1/metrics`
/// renders.
struct RemoteHealth {
    healthy: AtomicBool,
    probe_failures: Counter,
    ejections: Counter,
}

/// The prober thread's handle; stopping is a flag flip + join (the
/// loop sleeps in short ticks, so drop latency is ≤ ~50 ms).
struct Prober {
    stop: Arc<AtomicBool>,
    handle: thread::JoinHandle<()>,
}

/// A [`ServingStack`]-shaped client for a shard living in another
/// process: every call is a request to the remote's `/v1` API over a
/// pooled keep-alive [`HttpClient`].
///
/// Construction is eager: [`connect`](Self::connect) round-trips
/// `GET /v1/healthz` to learn the remote's frequencies and required
/// history lengths (cached — the front-end validates request length on
/// every forecast and must not pay a network hop for it), then starts
/// the prober.
pub struct RemoteShard {
    addr: String,
    pool: ClientPool,
    frequencies: Vec<Frequency>,
    required: BTreeMap<Frequency, usize>,
    health: Arc<RemoteHealth>,
    /// In-flight requests; mirrored into `inflight` (a [`Gauge`] has no
    /// arithmetic — the atomic is the source of truth).
    inflight_n: AtomicU64,
    inflight: Gauge,
    latency: Histogram,
    prober: Option<Prober>,
}

impl RemoteShard {
    /// Dial `addr` (`host:port`), learn its identity from
    /// `GET /v1/healthz`, and start the health prober. Fails fast if
    /// the peer is unreachable or serves nothing.
    pub fn connect(addr: &str, opts: RemoteOptions) -> Result<Self> {
        let pool = ClientPool::new(
            addr,
            ClientOptions {
                connect_timeout: opts.connect_timeout,
                read_timeout: opts.read_timeout,
            },
            opts.pool_size.max(1),
        );
        let doc = {
            let mut client = pool.get()?;
            let reply = client
                .request("GET", "/v1/healthz", None)
                .with_context(|| format!("probing remote shard {addr}"))?;
            if reply.code != 200 {
                bail!("remote shard {addr} healthz returned {}", reply.code);
            }
            Json::parse(&reply.body)
                .with_context(|| format!("remote shard {addr} healthz body"))?
        };
        let mut frequencies = Vec::new();
        for f in doc.get("frequencies")?.as_arr()? {
            frequencies.push(Frequency::parse(f.as_str()?)?);
        }
        if frequencies.is_empty() {
            bail!("remote shard {addr} serves no frequencies");
        }
        let mut required = BTreeMap::new();
        // Older servers predate `required_lengths`; the map stays empty
        // and required_length() reports the gap explicitly.
        if let Some(req) = doc.opt("required_lengths") {
            for (name, v) in req.as_obj()? {
                required.insert(Frequency::parse(name)?, v.as_usize()?);
            }
        }
        let health = Arc::new(RemoteHealth {
            healthy: AtomicBool::new(true),
            probe_failures: Counter::new(),
            ejections: Counter::new(),
        });
        let prober = Prober::start(addr, &opts, Arc::clone(&health));
        Ok(Self {
            addr: addr.to_string(),
            pool,
            frequencies,
            required,
            health,
            inflight_n: AtomicU64::new(0),
            inflight: Gauge::new(),
            latency: Histogram::new(),
            prober: Some(prober),
        })
    }

    /// The peer address this shard proxies to.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// One instrumented request on a pooled connection. The guard
    /// returns the connection to the pool on drop — unless the request
    /// left it mid-response (poisoned), in which case it is discarded.
    fn request(&self, method: &str, path: &str, body: Option<&str>)
               -> Result<HttpReply> {
        let mut client = self.pool.get()?;
        let n = self.inflight_n.fetch_add(1, Ordering::Relaxed) + 1;
        self.inflight.set(n);
        let start = Instant::now();
        let out = client.request(method, path, body);
        let n = self.inflight_n.fetch_sub(1, Ordering::Relaxed) - 1;
        self.inflight.set(n);
        if out.is_ok() {
            self.latency.observe(start.elapsed().as_secs_f64());
        }
        out.with_context(
            || format!("remote shard {}: {method} {path}", self.addr))
    }

    /// The unified error envelope, for non-2xx replies that carry one.
    fn error_envelope(reply: &HttpReply) -> Option<api::ErrorEnvelope> {
        api::ErrorEnvelope::from_json(&Json::parse(&reply.body).ok()?).ok()
    }

    /// Pull `error.message` out of the unified error envelope, falling
    /// back to the raw body for non-envelope responses.
    fn error_message(reply: &HttpReply) -> String {
        Self::error_envelope(reply)
            .map(|e| e.message)
            .unwrap_or_else(|| reply.body.clone())
    }

    fn fetch_healthz(&self) -> Result<Json> {
        let reply = self.request("GET", "/v1/healthz", None)?;
        if reply.code != 200 {
            bail!("remote shard {} healthz returned {}", self.addr,
                  reply.code);
        }
        Json::parse(&reply.body)
    }
}

impl Drop for RemoteShard {
    fn drop(&mut self) {
        if let Some(p) = self.prober.take() {
            p.stop.store(true, Ordering::Relaxed);
            let _ = p.handle.join();
        }
    }
}

impl ShardClient for RemoteShard {
    /// `POST /v1/forecast`. A remote `429` maps back to a typed
    /// [`QueueFull`] so the local front-end re-emits it as its own
    /// `429` — backpressure propagates across machines instead of
    /// flattening into a generic `500`.
    fn forecast(&self, freq: Frequency, req: ForecastRequest)
                -> Result<ForecastResponse> {
        let body = api::ForecastRequest {
            freq: Some(freq),
            id: Some(req.id.clone()),
            category: Some(req.category),
            values: req.values,
        }
        .to_json()
        .to_string();
        let reply = self.request("POST", "/v1/forecast", Some(&body))?;
        match reply.code {
            200 => {
                let resp =
                    api::ForecastResponse::from_json(&Json::parse(&reply.body)?)?;
                Ok(ForecastResponse {
                    id: resp.id,
                    forecast: resp.forecast,
                    generation: resp.generation,
                })
            }
            // The remote does not echo its queue limit; 0 is the
            // "unknown/unbounded" sentinel the type already defines.
            429 => Err(anyhow::Error::new(QueueFull { limit: 0 })),
            code => bail!("remote shard {} rejected the forecast ({code}): \
                           {}",
                          self.addr, Self::error_message(&reply)),
        }
    }

    fn submit(&self, freq: Frequency, req: ForecastRequest)
              -> Result<ResponseReceiver> {
        let out = ShardClient::forecast(self, freq, req);
        match out {
            // Backpressure surfaces synchronously, like the local pool.
            Err(e) if e.is::<QueueFull>() => Err(e),
            other => {
                let (tx, rx) = mpsc::channel();
                let _ = tx.send(other);
                Ok(rx)
            }
        }
    }

    /// `POST /v1/series/{id}/observe`. A remote `409 stale_observation`
    /// maps back to a typed [`StaleObservation`] so the local front-end
    /// re-emits its own `409` — the write guard propagates across
    /// machines like [`QueueFull`] backpressure does.
    fn observe(&self, freq: Frequency, id: &str, values: &[f32],
               t0: Option<u64>) -> Result<ObserveOutcome> {
        let body = api::ObserveRequest {
            freq: Some(freq),
            values: values.to_vec(),
            t0,
        }
        .to_json()
        .to_string();
        let path = format!("/v1/series/{id}/observe");
        let reply = self.request("POST", &path, Some(&body))?;
        match reply.code {
            200 => {
                let resp =
                    api::ObserveResponse::from_json(&Json::parse(&reply.body)?)?;
                Ok(ObserveOutcome {
                    observed: resp.observed,
                    generation: resp.generation,
                    new_series: resp.new_series,
                })
            }
            409 => {
                // Reconstruct the typed error from the envelope message
                // (our own wire format: "…already consumed N
                // observations"); `observed` falls back to 0 if a future
                // server rewords it — the type still routes the 409.
                let msg = Self::error_message(&reply);
                let observed = msg
                    .rsplit("consumed ")
                    .next()
                    .and_then(|s| s.split_whitespace().next())
                    .and_then(|s| s.parse().ok())
                    .unwrap_or(0);
                Err(anyhow::Error::new(StaleObservation {
                    observed,
                    t0: t0.unwrap_or(0),
                }))
            }
            429 => Err(anyhow::Error::new(QueueFull { limit: 0 })),
            code => bail!("remote shard {} rejected the observe ({code}): \
                           {}",
                          self.addr, Self::error_message(&reply)),
        }
    }

    /// `GET /v1/series/{id}/forecast`. A remote `404 unknown_series`
    /// maps back to a typed [`UnknownSeries`].
    fn series_forecast(&self, freq: Frequency, id: &str)
                       -> Result<ForecastResponse> {
        let path = format!("/v1/series/{id}/forecast?freq={}", freq.name());
        let reply = self.request("GET", &path, None)?;
        match reply.code {
            200 => {
                let resp =
                    api::ForecastResponse::from_json(&Json::parse(&reply.body)?)?;
                Ok(ForecastResponse {
                    id: resp.id,
                    forecast: resp.forecast,
                    generation: resp.generation,
                })
            }
            404 => Err(anyhow::Error::new(UnknownSeries {
                id: id.to_string(),
            })),
            code => bail!("remote shard {} rejected the stateful forecast \
                           ({code}): {}",
                          self.addr, Self::error_message(&reply)),
        }
    }

    /// `GET /v1/series/{id}/state`.
    fn series_record(&self, freq: Frequency, id: &str)
                     -> Result<SeriesRecord> {
        let path = format!("/v1/series/{id}/state?freq={}", freq.name());
        let reply = self.request("GET", &path, None)?;
        match reply.code {
            200 => {
                let st =
                    api::SeriesState::from_json(&Json::parse(&reply.body)?)?;
                Ok(SeriesRecord {
                    state: EsState {
                        level: st.level,
                        ring1: st.seasonality,
                        ring2: st.seasonality2,
                        observed: st.observed,
                    },
                    generation: st.generation,
                })
            }
            404 => Err(anyhow::Error::new(UnknownSeries {
                id: id.to_string(),
            })),
            code => bail!("remote shard {} rejected the state read \
                           ({code}): {}",
                          self.addr, Self::error_message(&reply)),
        }
    }

    fn stats_snapshot(&self) -> Result<BTreeMap<Frequency, ServiceStats>> {
        let reply = self.request("GET", "/v1/stats", None)?;
        if reply.code != 200 {
            bail!("remote shard {} stats returned {}", self.addr, reply.code);
        }
        let doc = Json::parse(&reply.body)?;
        let mut out = BTreeMap::new();
        for (name, j) in doc.get("serving")?.as_obj()? {
            out.insert(Frequency::parse(name)?, ServiceStats::from_json(j)?);
        }
        Ok(out)
    }

    fn reload(&self, freq: Frequency, _state: ModelState) -> Result<u64> {
        bail!("remote shard {}: an in-memory ModelState cannot be shipped \
               over the wire — use reload_checkpoint, whose {} checkpoint \
               path is resolved on the remote's own filesystem",
              self.addr, freq.name())
    }

    fn reload_checkpoint(&self, freq: Frequency, path: &Path) -> Result<u64> {
        let body = Json::obj(vec![
            ("freq", Json::str(freq.name())),
            ("checkpoint", Json::str(path.to_string_lossy().as_ref())),
        ])
        .to_string();
        let reply = self.request("POST", "/v1/reload", Some(&body))?;
        if reply.code != 200 {
            bail!("remote shard {} reload failed ({}): {}", self.addr,
                  reply.code, Self::error_message(&reply));
        }
        let doc = Json::parse(&reply.body)?;
        Ok(doc.get("generation")?.as_f64()? as u64)
    }

    fn generation(&self, freq: Frequency) -> Result<u64> {
        let doc = self.fetch_healthz()?;
        Ok(doc.get("generations")?.get(freq.name())?.as_f64()? as u64)
    }

    fn frequencies(&self) -> Vec<Frequency> {
        self.frequencies.clone()
    }

    fn required_length(&self, freq: Frequency) -> Result<usize> {
        self.required.get(&freq).copied().ok_or_else(|| {
            anyhow!("remote shard {} did not advertise a required length \
                     for {} (not served, or the remote predates \
                     `required_lengths` in /v1/healthz)",
                    self.addr, freq.name())
        })
    }

    fn healthz(&self) -> Result<()> {
        self.fetch_healthz().map(|_| ())
    }

    fn healthy(&self) -> bool {
        self.health.healthy.load(Ordering::Relaxed)
    }

    fn health(&self) -> ShardHealth {
        ShardHealth {
            kind: "remote",
            addr: Some(self.addr.clone()),
            healthy: self.health.healthy.load(Ordering::Relaxed),
            probe_failures: self.health.probe_failures.get(),
            ejections: self.health.ejections.get(),
        }
    }

    /// Per-remote series carry both the ring `shard` label (so
    /// [`Registry::unregister`]`("shard", label)` drops them with the
    /// shard's whole slice on removal) and the peer `addr` (what an
    /// operator actually greps for).
    fn bind_metrics(&self, reg: &Registry, shard: &str) {
        let labels = [("shard", shard), ("addr", self.addr.as_str())];
        reg.register_gauge(
            "fesrnn_remote_inflight",
            "Requests currently in flight to this remote shard.",
            &labels, &self.inflight);
        reg.register_histogram(
            "fesrnn_remote_request_seconds",
            "Round-trip latency of requests to this remote shard \
             (successful requests only).",
            &labels, &self.latency);
        reg.register_counter(
            "fesrnn_remote_probe_failures_total",
            "Failed health probes against this remote shard (a flapping \
             peer accumulates these without necessarily tripping a full \
             ejection).",
            &labels, &self.health.probe_failures);
        reg.register_counter(
            "fesrnn_remote_ejections_total",
            "Healthy-to-ejected transitions for this remote shard \
             (consecutive probe failures reached eject_after).",
            &labels, &self.health.ejections);
    }
}

impl Prober {
    fn start(addr: &str, opts: &RemoteOptions, health: Arc<RemoteHealth>)
             -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&stop);
        let addr = addr.to_string();
        let probe_opts = ClientOptions {
            connect_timeout: opts.probe_timeout,
            read_timeout: opts.probe_timeout,
        };
        let interval = opts.probe_interval.max(Duration::from_millis(1));
        let eject_after = opts.eject_after.max(1);
        let readmit_after = opts.readmit_after.max(1);
        let handle = thread::spawn(move || {
            probe_loop(&addr, &probe_opts, interval, eject_after,
                       readmit_after, &health, &flag);
        });
        Self { stop, handle }
    }
}

/// One probe: a fresh connection (deliberately not pooled — the dial
/// path is exactly what a dead peer fails first) and one healthz
/// round-trip under the probe deadline.
fn probe_once(addr: &str, opts: &ClientOptions) -> bool {
    match HttpClient::connect_with(addr, opts.clone()) {
        Ok(mut client) => matches!(
            client.request("GET", "/v1/healthz", None),
            Ok(reply) if reply.code == 200),
        Err(_) => false,
    }
}

/// Consecutive-failure ejection, probation readmission. Sleeps in
/// ≤50 ms ticks so a stop request (shard drop) is honored promptly.
fn probe_loop(addr: &str, opts: &ClientOptions, interval: Duration,
              eject_after: u32, readmit_after: u32, health: &RemoteHealth,
              stop: &AtomicBool) {
    let tick = Duration::from_millis(50).min(interval);
    let mut fails = 0u32;
    let mut oks = 0u32;
    'outer: loop {
        let mut slept = Duration::ZERO;
        while slept < interval {
            if stop.load(Ordering::Relaxed) {
                break 'outer;
            }
            let d = tick.min(interval - slept);
            thread::sleep(d);
            slept += d;
        }
        if stop.load(Ordering::Relaxed) {
            break;
        }
        if probe_once(addr, opts) {
            fails = 0;
            if health.healthy.load(Ordering::Relaxed) {
                continue;
            }
            oks += 1;
            if oks >= readmit_after {
                // Probation served: restore the routing mask. The ring
                // points never moved, so placement is exactly what it
                // was before the ejection.
                health.healthy.store(true, Ordering::Relaxed);
                oks = 0;
            }
        } else {
            health.probe_failures.inc();
            oks = 0;
            fails = fails.saturating_add(1);
            if fails >= eject_after && health.healthy.load(Ordering::Relaxed)
            {
                health.healthy.store(false, Ordering::Relaxed);
                health.ejections.inc();
            }
        }
    }
}

/// Hedge timer below this many recorded latencies falls back to
/// [`HEDGE_DEFAULT_DELAY`] — a p95 over a handful of samples is noise.
const HEDGE_MIN_SAMPLES: u64 = 32;

/// Cold-start hedge delay, used until the rolling window warms up.
const HEDGE_DEFAULT_DELAY: Duration = Duration::from_millis(25);

/// The rolling hedge timer: a sliding window of recent successful
/// forecast latencies whose p95 decides how long the primary replica
/// gets before the next one is fired. Self-tuning both ways — a fleet
/// that speeds up hedges sooner, one that slows down stops hedging —
/// and clamped to [1 ms, 1 s] so a pathological window cannot disable
/// hedging entirely or turn it into a duplicate-everything storm.
pub struct HedgeClock {
    // lint:lock-name(remote.hedge)
    window: Mutex<Quantiles>,
    pub(crate) hedges: Counter,
    pub(crate) hedge_wins: Counter,
}

impl Default for HedgeClock {
    fn default() -> Self {
        Self::new()
    }
}

impl HedgeClock {
    pub fn new() -> Self {
        Self {
            window: Mutex::new(Quantiles::new(4096)),
            hedges: Counter::new(),
            hedge_wins: Counter::new(),
        }
    }

    /// How long the primary gets before a hedge fires: the rolling p95
    /// once warmed up, [`HEDGE_DEFAULT_DELAY`] before.
    pub fn delay(&self) -> Duration {
        let w = self.window.lock().unwrap();
        if w.count() < HEDGE_MIN_SAMPLES {
            return HEDGE_DEFAULT_DELAY;
        }
        Duration::from_secs_f64(w.quantile(0.95).clamp(1e-3, 1.0))
    }

    /// Record one end-to-end forecast latency (winners only — a loser's
    /// latency is not what a client observed).
    pub fn record(&self, secs: f64) {
        self.window.lock().unwrap().record(secs);
    }

    /// Hedges fired (timer expiries, not failovers).
    pub fn hedges(&self) -> u64 {
        self.hedges.get()
    }

    /// Hedges where a non-primary replica answered first.
    pub fn hedge_wins(&self) -> u64 {
        self.hedge_wins.get()
    }
}

fn spawn_replica(idx: usize, client: Arc<dyn ShardClient>, freq: Frequency,
                 req: ForecastRequest,
                 tx: mpsc::Sender<(usize, Result<ForecastResponse>)>) {
    thread::spawn(move || {
        // A loser's send fails once the winner has returned and dropped
        // the receiver; its response is drained here and discarded.
        let _ = tx.send((idx, client.forecast(freq, req)));
    });
}

/// Replicated dispatch: fire `replicas[0]`, start the hedge timer, fire
/// the next replica on expiry (or immediately on a fast failure —
/// failover, not counted as a hedge); first non-error response wins.
/// With one replica this is a plain synchronous call — no thread is
/// spawned, preserving the unreplicated hot path.
pub(crate) fn hedged_forecast(clock: &HedgeClock,
                              replicas: &[Arc<dyn ShardClient>],
                              freq: Frequency, req: ForecastRequest)
                              -> Result<ForecastResponse> {
    let Some(primary) = replicas.first() else {
        bail!("no shards are running");
    };
    let start = Instant::now();
    if replicas.len() == 1 {
        let out = primary.forecast(freq, req);
        if out.is_ok() {
            clock.record(start.elapsed().as_secs_f64());
        }
        return out;
    }
    let (tx, rx) = mpsc::channel::<(usize, Result<ForecastResponse>)>();
    spawn_replica(0, Arc::clone(primary), freq, req.clone(), tx.clone());
    let mut next = 1usize;
    let mut outstanding = 1usize;
    let mut last_err: Option<anyhow::Error> = None;
    while outstanding > 0 {
        let msg = if next < replicas.len() {
            match rx.recv_timeout(clock.delay()) {
                Ok(m) => m,
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    clock.hedges.inc();
                    spawn_replica(next, Arc::clone(&replicas[next]), freq,
                                  req.clone(), tx.clone());
                    next += 1;
                    outstanding += 1;
                    continue;
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        } else {
            match rx.recv() {
                Ok(m) => m,
                Err(_) => break,
            }
        };
        outstanding -= 1;
        let (idx, out) = msg;
        match out {
            Ok(resp) => {
                if idx > 0 {
                    clock.hedge_wins.inc();
                }
                clock.record(start.elapsed().as_secs_f64());
                return Ok(resp);
            }
            Err(e) => {
                // Keep the most informative error: a typed QueueFull
                // (a saturated replica → the client should back off)
                // beats a transport error from the other one.
                let keep_old = matches!(&last_err,
                                        Some(p) if p.is::<QueueFull>())
                    && !e.is::<QueueFull>();
                if !keep_old {
                    last_err = Some(e);
                }
                if outstanding == 0 && next < replicas.len() {
                    // Fast failure with replicas to spare: synchronous
                    // failover (the primary's answer is already known
                    // to be an error — nothing to hedge against).
                    spawn_replica(next, Arc::clone(&replicas[next]), freq,
                                  req.clone(), tx.clone());
                    next += 1;
                    outstanding += 1;
                }
            }
        }
    }
    Err(last_err
        .unwrap_or_else(|| anyhow!("every replica failed without a report")))
}

#[cfg(test)]
mod tests {
    use super::*;

    const FREQ: Frequency = Frequency::Quarterly;

    /// A scriptable in-process ShardClient for hedging tests.
    struct Stub {
        delay: Duration,
        outcome: StubOutcome,
        calls: AtomicU64,
    }

    enum StubOutcome {
        Ok(&'static str),
        Fail,
        QueueFull,
    }

    impl Stub {
        fn new(delay_ms: u64, outcome: StubOutcome) -> Arc<Self> {
            Arc::new(Self {
                delay: Duration::from_millis(delay_ms),
                outcome,
                calls: AtomicU64::new(0),
            })
        }
    }

    impl ShardClient for Stub {
        fn forecast(&self, _freq: Frequency, req: ForecastRequest)
                    -> Result<ForecastResponse> {
            self.calls.fetch_add(1, Ordering::Relaxed);
            thread::sleep(self.delay);
            match self.outcome {
                StubOutcome::Ok(tag) => Ok(ForecastResponse {
                    id: format!("{}:{}", tag, req.id),
                    forecast: vec![1.0],
                    generation: 7,
                }),
                StubOutcome::Fail => bail!("stub is down"),
                StubOutcome::QueueFull => {
                    Err(anyhow::Error::new(QueueFull { limit: 4 }))
                }
            }
        }

        fn submit(&self, freq: Frequency, req: ForecastRequest)
                  -> Result<ResponseReceiver> {
            let (tx, rx) = mpsc::channel();
            let _ = tx.send(ShardClient::forecast(self, freq, req));
            Ok(rx)
        }

        fn stats_snapshot(&self)
                          -> Result<BTreeMap<Frequency, ServiceStats>> {
            Ok(BTreeMap::new())
        }

        fn reload(&self, _freq: Frequency, _state: ModelState)
                  -> Result<u64> {
            bail!("stub")
        }

        fn reload_checkpoint(&self, _freq: Frequency, _path: &Path)
                             -> Result<u64> {
            bail!("stub")
        }

        fn generation(&self, _freq: Frequency) -> Result<u64> {
            Ok(7)
        }

        fn frequencies(&self) -> Vec<Frequency> {
            vec![FREQ]
        }

        fn required_length(&self, _freq: Frequency) -> Result<usize> {
            Ok(1)
        }

        fn healthz(&self) -> Result<()> {
            Ok(())
        }

        fn health(&self) -> ShardHealth {
            ShardHealth::local()
        }

        fn bind_metrics(&self, _reg: &Registry, _shard: &str) {}
    }

    fn req(id: &str) -> ForecastRequest {
        ForecastRequest {
            id: id.to_string(),
            values: vec![1.0; 8],
            category: crate::config::Category::Other,
        }
    }

    #[test]
    fn hedge_clock_uses_default_until_warm() {
        let clock = HedgeClock::new();
        assert_eq!(clock.delay(), HEDGE_DEFAULT_DELAY);
        for _ in 0..(HEDGE_MIN_SAMPLES - 1) {
            clock.record(0.004);
        }
        assert_eq!(clock.delay(), HEDGE_DEFAULT_DELAY,
                   "one sample short of warm must still use the default");
        clock.record(0.004);
        let d = clock.delay();
        assert!(d >= Duration::from_millis(3) && d <= Duration::from_millis(6),
                "warmed delay should track the recorded p95, got {d:?}");
    }

    #[test]
    fn hedge_clock_clamps_pathological_windows() {
        let clock = HedgeClock::new();
        for _ in 0..HEDGE_MIN_SAMPLES {
            clock.record(0.000_001);
        }
        assert_eq!(clock.delay(), Duration::from_millis(1),
                   "sub-ms p95 clamps to the 1 ms floor");
        let clock = HedgeClock::new();
        for _ in 0..HEDGE_MIN_SAMPLES {
            clock.record(30.0);
        }
        assert_eq!(clock.delay(), Duration::from_secs(1),
                   "a stalled fleet clamps to the 1 s ceiling");
    }

    #[test]
    fn single_replica_is_a_plain_call() {
        let clock = HedgeClock::new();
        let a = Stub::new(0, StubOutcome::Ok("a"));
        let reps: Vec<Arc<dyn ShardClient>> = vec![a.clone()];
        let resp = hedged_forecast(&clock, &reps, FREQ, req("k")).unwrap();
        assert_eq!(resp.id, "a:k");
        assert_eq!(clock.hedges(), 0);
        assert_eq!(a.calls.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn slow_primary_is_hedged_and_secondary_wins() {
        let clock = HedgeClock::new();
        // Warm the clock to a ~4 ms hedge delay so the test is quick.
        for _ in 0..HEDGE_MIN_SAMPLES {
            clock.record(0.004);
        }
        let slow = Stub::new(300, StubOutcome::Ok("slow"));
        let fast = Stub::new(0, StubOutcome::Ok("fast"));
        let reps: Vec<Arc<dyn ShardClient>> = vec![slow.clone(), fast.clone()];
        let t0 = Instant::now();
        let resp = hedged_forecast(&clock, &reps, FREQ, req("k")).unwrap();
        assert_eq!(resp.id, "fast:k", "the hedge must win");
        assert!(t0.elapsed() < Duration::from_millis(200),
                "hedged latency must not wait out the slow primary");
        assert_eq!(clock.hedges(), 1);
        assert_eq!(clock.hedge_wins(), 1);
    }

    #[test]
    fn fast_primary_never_hedges() {
        let clock = HedgeClock::new();
        // Warm the clock to a generous 500 ms hedge delay so scheduler
        // jitter on a loaded CI machine cannot fire a spurious hedge.
        for _ in 0..HEDGE_MIN_SAMPLES {
            clock.record(0.5);
        }
        let fast = Stub::new(0, StubOutcome::Ok("fast"));
        let slow = Stub::new(50, StubOutcome::Ok("slow"));
        let reps: Vec<Arc<dyn ShardClient>> = vec![fast, slow.clone()];
        let resp = hedged_forecast(&clock, &reps, FREQ, req("k")).unwrap();
        assert_eq!(resp.id, "fast:k");
        assert_eq!(clock.hedges(), 0, "no timer expiry, no hedge");
        assert_eq!(slow.calls.load(Ordering::Relaxed), 0,
                   "the secondary must not even be contacted");
    }

    #[test]
    fn fast_primary_failure_fails_over_without_counting_a_hedge() {
        let clock = HedgeClock::new();
        // Generous delay: the failure must beat the hedge timer.
        for _ in 0..HEDGE_MIN_SAMPLES {
            clock.record(0.5);
        }
        let dead = Stub::new(0, StubOutcome::Fail);
        let ok = Stub::new(0, StubOutcome::Ok("b"));
        let reps: Vec<Arc<dyn ShardClient>> = vec![dead, ok];
        let resp = hedged_forecast(&clock, &reps, FREQ, req("k")).unwrap();
        assert_eq!(resp.id, "b:k");
        assert_eq!(clock.hedges(), 0,
                   "failover on a known error is not a hedge");
        assert_eq!(clock.hedge_wins(), 1,
                   "a non-primary response still counts as a win");
    }

    #[test]
    fn all_replicas_failing_reports_an_error() {
        let clock = HedgeClock::new();
        let reps: Vec<Arc<dyn ShardClient>> = vec![
            Stub::new(0, StubOutcome::Fail),
            Stub::new(0, StubOutcome::Fail),
        ];
        let err = hedged_forecast(&clock, &reps, FREQ, req("k")).unwrap_err();
        assert!(format!("{err:#}").contains("stub is down"));
    }

    #[test]
    fn queue_full_from_every_replica_stays_typed() {
        let clock = HedgeClock::new();
        let reps: Vec<Arc<dyn ShardClient>> = vec![
            Stub::new(0, StubOutcome::QueueFull),
            Stub::new(0, StubOutcome::Fail),
        ];
        let err = hedged_forecast(&clock, &reps, FREQ, req("k")).unwrap_err();
        assert!(err.is::<QueueFull>(),
                "a saturated replica's QueueFull must win the error \
                 triage so the front-end sheds with 429, got: {err:#}");
    }

    #[test]
    fn empty_replica_set_errors() {
        let clock = HedgeClock::new();
        let reps: Vec<Arc<dyn ShardClient>> = Vec::new();
        assert!(hedged_forecast(&clock, &reps, FREQ, req("k")).is_err());
    }

    #[test]
    fn local_stack_health_is_static() {
        let h = ShardHealth::local();
        assert_eq!(h.kind, "local");
        assert!(h.healthy && h.addr.is_none());
    }
}
