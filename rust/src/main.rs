//! `fast-esrnn` CLI — the Layer-3 entrypoint.
//!
//! Subcommands:
//!   data-gen   generate the synthetic M4-like corpus (+ Tables 2/3 report)
//!   train      train ES-RNN for one or more frequencies, save checkpoints
//!   evaluate   score a checkpoint on the test holdout
//!   baselines  run the classical baselines (incl. the M4 Comb benchmark)
//!   serve      demo of the dynamic-batching forecast service
//!
//! `--backend native` (the default) runs everything on the pure-Rust
//! backend — no artifacts, no XLA, no Python. `--backend pjrt` runs from
//! the AOT artifacts in `--artifacts` (requires `--features pjrt`).

use anyhow::{bail, Result};

use fast_esrnn::baselines::{all_baselines, Comb, Forecaster};
use fast_esrnn::config::{Category, Frequency, NetworkConfig, TrainConfig,
                         ALL_CATEGORIES, MODELED_FREQS};
use fast_esrnn::coordinator::{checkpoint, EvalSplit, Trainer};
use fast_esrnn::data::{self, stats, Corpus, GenOptions};
use fast_esrnn::forecast::{ForecastRequest, ForecastService, ServiceOptions};
use fast_esrnn::metrics::{mase, smape};
use fast_esrnn::runtime::{backend_with_artifacts, Backend};
use fast_esrnn::util::cli::{Args, Cli};

/// Build the backend selected by `--backend` / `--artifacts`.
fn backend_from_args(a: &Args) -> Result<Box<dyn Backend>> {
    backend_with_artifacts(a.get("backend"),
                           Some(std::path::Path::new(a.get("artifacts"))))
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("{e:#}");
        std::process::exit(1);
    }
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        bail!("usage: fast-esrnn <data-gen|train|evaluate|baselines|serve> \
               [options]\n       fast-esrnn <cmd> --help for details");
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "data-gen" => cmd_data_gen(rest),
        "train" => cmd_train(rest),
        "evaluate" => cmd_evaluate(rest),
        "baselines" => cmd_baselines(rest),
        "serve" => cmd_serve(rest),
        other => bail!("unknown command `{other}`"),
    }
}

fn load_or_gen_corpus(corpus_path: &str, scale: usize, seed: u64) -> Result<Corpus> {
    if !corpus_path.is_empty() && std::path::Path::new(corpus_path).exists() {
        println!("loading corpus from {corpus_path}");
        return data::csv::load(corpus_path);
    }
    println!("generating synthetic M4-like corpus (scale 1/{scale}, seed {seed})");
    Ok(data::generate(&GenOptions { scale, seed, freqs: None }))
}

fn parse_freqs(list: &[String]) -> Result<Vec<Frequency>> {
    if list.len() == 1 && list[0] == "all" {
        return Ok(MODELED_FREQS.to_vec());
    }
    list.iter().map(|s| Frequency::parse(s)).collect()
}

// ---------------------------------------------------------------------

fn cmd_data_gen(args: &[String]) -> Result<()> {
    let cli = Cli::new("data-gen", "generate the synthetic M4-like corpus")
        .opt("scale", "100", "divide Table 2 counts by this")
        .opt("seed", "20190603", "corpus RNG seed")
        .opt("out", "", "write corpus CSV here (optional)")
        .flag("report", "print Tables 2/3-style summaries");
    let a = cli.parse(args)?;
    let corpus = data::generate(&GenOptions {
        scale: a.get_usize("scale")?,
        seed: a.get_u64("seed")?,
        freqs: None,
    });
    println!("generated {} series", corpus.len());
    if a.get_flag("report") {
        println!("\n== Table 2 analogue: counts by frequency × category ==");
        print!("{}", stats::render_count_table(&corpus));
        println!("\n== Table 3 analogue: series length statistics ==");
        print!("{}", stats::render_length_table(&corpus));
        println!("\n== §5.2 equalization retention ==");
        print!("{}", stats::retention_report(&corpus));
    }
    let out = a.get("out");
    if !out.is_empty() {
        data::csv::save(&corpus, out)?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_train(args: &[String]) -> Result<()> {
    let cli = Cli::new("train", "train ES-RNN per frequency")
        .opt("backend", "native", "execution backend: native or pjrt")
        .opt("artifacts", "artifacts", "artifact directory (pjrt backend)")
        .opt("freqs", "all", "comma list: yearly,quarterly,monthly or `all`")
        .opt("scale", "100", "synthetic corpus scale divisor")
        .opt("corpus", "", "load corpus CSV instead of generating")
        .opt("epochs", "15", "training epochs")
        .opt("batch-size", "64", "train batch size (needs matching artifact)")
        .opt("lr", "0.001", "Adam learning rate")
        .opt("seed", "42", "training seed")
        .opt("checkpoint-dir", "checkpoints", "save checkpoints here")
        .flag("quiet", "suppress per-epoch logs");
    let a = cli.parse(args)?;
    let backend = backend_from_args(&a)?;
    println!("backend: {}", backend.platform());
    let corpus = load_or_gen_corpus(a.get("corpus"), a.get_usize("scale")?,
                                    20190603)?;
    let freqs = parse_freqs(&a.get_str_list("freqs"))?;
    std::fs::create_dir_all(a.get("checkpoint-dir"))?;

    for freq in freqs {
        let tc = TrainConfig {
            epochs: a.get_usize("epochs")?,
            batch_size: a.get_usize("batch-size")?,
            learning_rate: a.get_f32("lr")?,
            seed: a.get_u64("seed")?,
            ..Default::default()
        };
        println!("\n=== training {} ({} epochs, batch {}) ===",
                 freq.name(), tc.epochs, tc.batch_size);
        let mut trainer = Trainer::new(backend.as_ref(), freq, &corpus, tc)?;
        println!("  {} series after §5.2 equalization ({} discarded)",
                 trainer.series_count(), trainer.set.discarded);
        let report = trainer.train(!a.get_flag("quiet"))?;
        let test = trainer.evaluate(EvalSplit::Test)?;
        println!("  [{}] test sMAPE {:.3}  MASE {:.3}  ({} series, {:.1}s, \
                  {} steps)",
                 freq.name(), test.smape, test.mase, test.count,
                 report.train_secs, report.steps);
        let path = format!("{}/{}.json", a.get("checkpoint-dir"), freq.name());
        checkpoint::save(&path, freq.name(), &trainer.state, &trainer.store)?;
        println!("  checkpoint → {path}");
        if !a.get_flag("quiet") {
            println!("{}", trainer.telemetry.report());
        }
    }
    Ok(())
}

fn cmd_evaluate(args: &[String]) -> Result<()> {
    let cli = Cli::new("evaluate", "score a checkpoint on the test holdout")
        .opt("backend", "native", "execution backend: native or pjrt")
        .opt("artifacts", "artifacts", "artifact directory (pjrt backend)")
        .opt("freqs", "all", "frequencies")
        .opt("scale", "100", "synthetic corpus scale divisor")
        .opt("corpus", "", "corpus CSV (must match training corpus)")
        .opt("checkpoint-dir", "checkpoints", "checkpoint directory")
        .opt("batch-size", "64", "batch artifact used for store sizing")
        .opt("seed", "42", "seed (must match training for primer layout)");
    let a = cli.parse(args)?;
    let backend = backend_from_args(&a)?;
    let corpus = load_or_gen_corpus(a.get("corpus"), a.get_usize("scale")?,
                                    20190603)?;
    let freqs = parse_freqs(&a.get_str_list("freqs"))?;

    println!("\n{:<10} {:>8} {:>8} {:>8}  per-category sMAPE", "freq",
             "series", "sMAPE", "MASE");
    for freq in freqs {
        let tc = TrainConfig {
            batch_size: a.get_usize("batch-size")?,
            seed: a.get_u64("seed")?,
            ..Default::default()
        };
        let mut trainer = Trainer::new(backend.as_ref(), freq, &corpus, tc)?;
        let path = format!("{}/{}.json", a.get("checkpoint-dir"), freq.name());
        checkpoint::load(&path, &mut trainer.state, &mut trainer.store)?;
        let test = trainer.evaluate(EvalSplit::Test)?;
        let cats: Vec<String> = ALL_CATEGORIES
            .iter()
            .filter_map(|c| {
                test.category_smape(c.name())
                    .map(|v| format!("{}={:.2}", c.name(), v))
            })
            .collect();
        println!("{:<10} {:>8} {:>8.3} {:>8.3}  {}", freq.name(), test.count,
                 test.smape, test.mase, cats.join(" "));
    }
    Ok(())
}

fn cmd_baselines(args: &[String]) -> Result<()> {
    let cli = Cli::new("baselines", "classical baselines incl. M4 Comb")
        .opt("freqs", "all", "frequencies")
        .opt("scale", "100", "synthetic corpus scale divisor")
        .opt("corpus", "", "corpus CSV");
    let a = cli.parse(args)?;
    let corpus = load_or_gen_corpus(a.get("corpus"), a.get_usize("scale")?,
                                    20190603)?;
    let freqs = parse_freqs(&a.get_str_list("freqs"))?;

    for freq in freqs {
        let net = NetworkConfig::for_freq(freq)?;
        let set = data::split_corpus(&corpus, &net)?;
        println!("\n=== {} ({} series) ===", freq.name(), set.series.len());
        println!("{:<14} {:>8} {:>8}", "method", "sMAPE", "MASE");
        for method in all_baselines() {
            let mut s_acc = 0.0;
            let mut m_acc = 0.0;
            for sp in &set.series {
                let fc = method.forecast(&sp.refit, net.seasonality, net.horizon);
                s_acc += smape(&fc, &sp.test);
                m_acc += mase(&fc, &sp.test, sp.mase_scale);
            }
            let n = set.series.len() as f64;
            println!("{:<14} {:>8.3} {:>8.3}", method.name(), s_acc / n,
                     m_acc / n);
        }
    }
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let cli = Cli::new("serve", "demo the dynamic-batching forecast service")
        .opt("backend", "native", "execution backend: native or pjrt")
        .opt("artifacts", "artifacts", "artifact directory (pjrt backend)")
        .opt("freq", "quarterly", "frequency to serve")
        .opt("checkpoint-dir", "checkpoints", "checkpoint directory")
        .opt("requests", "64", "number of demo requests")
        .opt("scale", "200", "corpus scale for demo request data");
    let a = cli.parse(args)?;
    let freq = Frequency::parse(a.get("freq"))?;
    let net = NetworkConfig::for_freq(freq)?;

    // Load a trained model if present; otherwise serve with fresh weights
    // (still exercises the full service path).
    let state = {
        let backend = backend_from_args(&a)?;
        let mut state = fast_esrnn::coordinator::ModelState::init(
            backend.as_ref(), freq.name(), 42)?;
        let ckpt = format!("{}/{}.json", a.get("checkpoint-dir"), freq.name());
        if std::path::Path::new(&ckpt).exists() {
            println!("serving RNN weights from {ckpt}");
            let text = std::fs::read_to_string(&ckpt)?;
            let doc = fast_esrnn::util::json::Json::parse(&text)?;
            let n = doc.get("n_series")?.as_usize()?;
            let primer = fast_esrnn::hw::Primer {
                alpha_logit: 0.0,
                gamma_logit: 0.0,
                gamma2_logit: 0.0,
                log_s_init: vec![0.0; net.total_seasonality()],
            };
            let mut store = fast_esrnn::coordinator::ParamStore::from_primers_dual(
                &vec![primer; n], net.seasonality, net.seasonality2)?;
            checkpoint::load(&ckpt, &mut state, &mut store)?;
        }
        state
    }; // backend dropped: the service constructs its own on its thread

    let backend_name = a.get("backend").to_string();
    let artifacts = std::path::PathBuf::from(a.get("artifacts"));
    let service = ForecastService::start(
        move || backend_with_artifacts(&backend_name, Some(&artifacts)),
        freq, state, ServiceOptions::default())?;

    // Fire demo requests from generated series.
    let corpus = data::generate(&GenOptions {
        scale: a.get_usize("scale")?,
        seed: 7,
        freqs: Some(vec![freq]),
    });
    let n_req = a.get_usize("requests")?;
    let mut receivers = Vec::new();
    let t0 = std::time::Instant::now();
    let mut sent = 0usize;
    for s in corpus.series.iter().cycle() {
        if sent >= n_req {
            break;
        }
        if s.len() < net.length {
            continue;
        }
        receivers.push(service.handle.submit(ForecastRequest {
            id: s.id.clone(),
            values: s.values.clone(),
            category: s.category,
        })?);
        sent += 1;
    }
    let mut ok = 0usize;
    for rx in receivers {
        let resp = rx.recv()??;
        assert_eq!(resp.forecast.len(), net.horizon);
        ok += 1;
    }
    let secs = t0.elapsed().as_secs_f64();
    let st = service.handle.stats()?;
    println!("served {ok}/{n_req} requests in {:.3}s \
              ({:.1} req/s; {} batches, {} padded slots)",
             secs, ok as f64 / secs, st.batches, st.padded_slots);

    // Show one example forecast vs the Comb baseline for color.
    if let Some(s) = corpus.series.iter().find(|s| s.len() >= net.length) {
        let resp = service.handle.forecast(ForecastRequest {
            id: s.id.clone(),
            values: s.values.clone(),
            category: Category::Other,
        })?;
        let comb = Comb.forecast(&s.values, net.seasonality, net.horizon);
        println!("\nexample `{}`:\n  es-rnn: {:?}\n  comb:   {:?}", s.id,
                 &resp.forecast[..4.min(resp.forecast.len())],
                 &comb[..4.min(comb.len())]);
    }
    Ok(())
}
