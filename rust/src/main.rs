//! `fast-esrnn` CLI — the Layer-3 entrypoint.
//!
//! Subcommands:
//!   data-gen   generate the synthetic M4-like corpus (+ Tables 2/3 report)
//!   train      train ES-RNN for one or more frequencies, save checkpoints
//!   evaluate   score a checkpoint on the test holdout
//!   baselines  run the classical baselines (incl. the M4 Comb benchmark)
//!   serve      the serving stack: per-frequency worker pools, model
//!              hot-swap, optional HTTP front-end (`--http ADDR`)
//!   top        live terminal dashboard over a running front-end's
//!              `/v1/metrics` (queue depth, shed rate, latency quantiles)
//!
//! `--backend native` (the default) runs everything on the pure-Rust
//! backend — no artifacts, no XLA, no Python. `--backend pjrt` runs from
//! the AOT artifacts in `--artifacts` (requires `--features pjrt`).

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use fast_esrnn::baselines::{all_baselines, Forecaster};
use fast_esrnn::config::{Category, Frequency, NetworkConfig, TrainConfig,
                         ALL_CATEGORIES, MODELED_FREQS};
use fast_esrnn::coordinator::{checkpoint, EvalSplit, ModelState, Trainer};
use fast_esrnn::data::{self, stats, Corpus, GenOptions};
use fast_esrnn::forecast::{api, http, ForecastRequest, HttpServer,
                           QueueFull, RemoteOptions, RemoteShard,
                           ServiceOptions, ServingStack, ShardedStack};
use fast_esrnn::metrics::{mase, smape};
use fast_esrnn::runtime::{backend_with_artifacts, Backend};
use fast_esrnn::telemetry::promtext::{self, Sample};
use fast_esrnn::util::cli::{Args, Cli};
use fast_esrnn::util::json::Json;

/// Build the backend selected by `--backend` / `--artifacts`.
fn backend_from_args(a: &Args) -> Result<Box<dyn Backend>> {
    backend_with_artifacts(a.get("backend"),
                           Some(std::path::Path::new(a.get("artifacts"))))
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("{e:#}");
        std::process::exit(1);
    }
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        bail!("usage: fast-esrnn \
               <data-gen|train|evaluate|baselines|serve|top> \
               [options]\n       fast-esrnn <cmd> --help for details");
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "data-gen" => cmd_data_gen(rest),
        "train" => cmd_train(rest),
        "evaluate" => cmd_evaluate(rest),
        "baselines" => cmd_baselines(rest),
        "serve" => cmd_serve(rest),
        "top" => cmd_top(rest),
        other => bail!("unknown command `{other}`"),
    }
}

fn load_or_gen_corpus(corpus_path: &str, scale: usize, seed: u64) -> Result<Corpus> {
    if !corpus_path.is_empty() && std::path::Path::new(corpus_path).exists() {
        println!("loading corpus from {corpus_path}");
        return data::csv::load(corpus_path);
    }
    println!("generating synthetic M4-like corpus (scale 1/{scale}, seed {seed})");
    data::generate(&GenOptions { scale, seed, freqs: None })
}

/// Newest checkpoint for `freq` in `dir` by modification time (a retrain
/// in the other format must win over a stale file); `load` sniffs the
/// actual format by magic either way.
fn find_checkpoint(dir: &str, freq: Frequency) -> Option<PathBuf> {
    ["bin", "json"]
        .iter()
        .map(|ext| PathBuf::from(format!("{dir}/{}.{ext}", freq.name())))
        .filter_map(|p| {
            let modified = std::fs::metadata(&p).and_then(|m| m.modified()).ok()?;
            Some((modified, p))
        })
        .max_by_key(|(modified, _)| *modified)
        .map(|(_, p)| p)
}

fn parse_freqs(list: &[String]) -> Result<Vec<Frequency>> {
    if list.len() == 1 && list[0] == "all" {
        return Ok(MODELED_FREQS.to_vec());
    }
    list.iter().map(|s| Frequency::parse(s)).collect()
}

// ---------------------------------------------------------------------

fn cmd_data_gen(args: &[String]) -> Result<()> {
    let cli = Cli::new("data-gen", "generate the synthetic M4-like corpus")
        .opt("scale", "100", "divide Table 2 counts by this")
        .opt("seed", "20190603", "corpus RNG seed")
        .opt("out", "", "write corpus CSV here (optional)")
        .flag("report", "print Tables 2/3-style summaries");
    let a = cli.parse(args)?;
    let corpus = data::generate(&GenOptions {
        scale: a.get_usize("scale")?,
        seed: a.get_u64("seed")?,
        freqs: None,
    })?;
    println!("generated {} series", corpus.len());
    if a.get_flag("report") {
        println!("\n== Table 2 analogue: counts by frequency × category ==");
        print!("{}", stats::render_count_table(&corpus));
        println!("\n== Table 3 analogue: series length statistics ==");
        print!("{}", stats::render_length_table(&corpus));
        println!("\n== §5.2 equalization retention ==");
        print!("{}", stats::retention_report(&corpus));
    }
    let out = a.get("out");
    if !out.is_empty() {
        data::csv::save(&corpus, out)?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_train(args: &[String]) -> Result<()> {
    let cli = Cli::new("train", "train ES-RNN per frequency")
        .opt("backend", "native", "execution backend: native or pjrt")
        .opt("artifacts", "artifacts", "artifact directory (pjrt backend)")
        .opt("freqs", "all", "comma list: yearly,quarterly,monthly or `all`")
        .opt("scale", "100", "synthetic corpus scale divisor")
        .opt("corpus", "", "load corpus CSV instead of generating")
        .opt("epochs", "15", "training epochs")
        .opt("batch-size", "64", "train batch size (needs matching artifact)")
        .opt("lr", "0.001", "Adam learning rate")
        .opt("seed", "42", "training seed")
        .opt("checkpoint-dir", "checkpoints", "save checkpoints here")
        .opt("checkpoint-format", "json",
             "checkpoint format: json or bin (compact binary)")
        .flag("quiet", "suppress per-epoch logs");
    let a = cli.parse(args)?;
    let ckpt_ext = match a.get("checkpoint-format") {
        "json" | "bin" => a.get("checkpoint-format"),
        other => bail!("unknown checkpoint format `{other}` (json or bin)"),
    };
    let backend = backend_from_args(&a)?;
    println!("backend: {}", backend.platform());
    let corpus = load_or_gen_corpus(a.get("corpus"), a.get_usize("scale")?,
                                    20190603)?;
    let freqs = parse_freqs(&a.get_str_list("freqs"))?;
    std::fs::create_dir_all(a.get("checkpoint-dir"))?;

    for freq in freqs {
        let tc = TrainConfig {
            epochs: a.get_usize("epochs")?,
            batch_size: a.get_usize("batch-size")?,
            learning_rate: a.get_f32("lr")?,
            seed: a.get_u64("seed")?,
            ..Default::default()
        };
        println!("\n=== training {} ({} epochs, batch {}) ===",
                 freq.name(), tc.epochs, tc.batch_size);
        let mut trainer = Trainer::new(backend.as_ref(), freq, &corpus, tc)?;
        println!("  {} series after §5.2 equalization ({} discarded)",
                 trainer.series_count(), trainer.set.discarded);
        let report = trainer.train(!a.get_flag("quiet"))?;
        let test = trainer.evaluate(EvalSplit::Test)?;
        println!("  [{}] test sMAPE {:.3}  MASE {:.3}  ({} series, {:.1}s, \
                  {} steps)",
                 freq.name(), test.smape, test.mase, test.count,
                 report.train_secs, report.steps);
        let path = format!("{}/{}.{ckpt_ext}", a.get("checkpoint-dir"),
                           freq.name());
        checkpoint::save(&path, freq.name(), &trainer.state, &trainer.store)?;
        println!("  checkpoint → {path}");
        if !a.get_flag("quiet") {
            println!("{}", trainer.telemetry.report());
        }
    }
    Ok(())
}

fn cmd_evaluate(args: &[String]) -> Result<()> {
    let cli = Cli::new("evaluate", "score a checkpoint on the test holdout")
        .opt("backend", "native", "execution backend: native or pjrt")
        .opt("artifacts", "artifacts", "artifact directory (pjrt backend)")
        .opt("freqs", "all", "frequencies")
        .opt("scale", "100", "synthetic corpus scale divisor")
        .opt("corpus", "", "corpus CSV (must match training corpus)")
        .opt("checkpoint-dir", "checkpoints", "checkpoint directory")
        .opt("batch-size", "64", "batch artifact used for store sizing")
        .opt("seed", "42", "seed (must match training for primer layout)");
    let a = cli.parse(args)?;
    let backend = backend_from_args(&a)?;
    let corpus = load_or_gen_corpus(a.get("corpus"), a.get_usize("scale")?,
                                    20190603)?;
    let freqs = parse_freqs(&a.get_str_list("freqs"))?;

    println!("\n{:<10} {:>8} {:>8} {:>8}  per-category sMAPE", "freq",
             "series", "sMAPE", "MASE");
    for freq in freqs {
        let tc = TrainConfig {
            batch_size: a.get_usize("batch-size")?,
            seed: a.get_u64("seed")?,
            ..Default::default()
        };
        let mut trainer = Trainer::new(backend.as_ref(), freq, &corpus, tc)?;
        let path = find_checkpoint(a.get("checkpoint-dir"), freq)
            .ok_or_else(|| anyhow::anyhow!(
                "no {0}.bin or {0}.json checkpoint in {1}", freq.name(),
                a.get("checkpoint-dir")))?;
        checkpoint::load(&path, &mut trainer.state, &mut trainer.store)?;
        let test = trainer.evaluate(EvalSplit::Test)?;
        let cats: Vec<String> = ALL_CATEGORIES
            .iter()
            .filter_map(|c| {
                test.category_smape(c.name())
                    .map(|v| format!("{}={:.2}", c.name(), v))
            })
            .collect();
        println!("{:<10} {:>8} {:>8.3} {:>8.3}  {}", freq.name(), test.count,
                 test.smape, test.mase, cats.join(" "));
    }
    Ok(())
}

fn cmd_baselines(args: &[String]) -> Result<()> {
    let cli = Cli::new("baselines", "classical baselines incl. M4 Comb")
        .opt("freqs", "all", "frequencies")
        .opt("scale", "100", "synthetic corpus scale divisor")
        .opt("corpus", "", "corpus CSV");
    let a = cli.parse(args)?;
    let corpus = load_or_gen_corpus(a.get("corpus"), a.get_usize("scale")?,
                                    20190603)?;
    let freqs = parse_freqs(&a.get_str_list("freqs"))?;

    for freq in freqs {
        let net = NetworkConfig::for_freq(freq)?;
        let set = data::split_corpus(&corpus, &net)?;
        println!("\n=== {} ({} series) ===", freq.name(), set.series.len());
        println!("{:<14} {:>8} {:>8}", "method", "sMAPE", "MASE");
        for method in all_baselines() {
            let mut s_acc = 0.0;
            let mut m_acc = 0.0;
            for sp in &set.series {
                let fc = method.forecast(&sp.refit, net.seasonality, net.horizon);
                s_acc += smape(&fc, &sp.test);
                m_acc += mase(&fc, &sp.test, sp.mase_scale);
            }
            let n = set.series.len() as f64;
            println!("{:<14} {:>8.3} {:>8.3}", method.name(), s_acc / n,
                     m_acc / n);
        }
    }
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let cli = Cli::new("serve", "serve forecasts from sharded per-frequency \
                                 worker pools with model hot-swap")
        .opt("backend", "native", "execution backend: native or pjrt")
        .opt("artifacts", "artifacts", "artifact directory (pjrt backend)")
        .opt("freqs", "quarterly",
             "comma list of frequencies to serve, or `all`")
        .opt("checkpoint-dir", "checkpoints", "checkpoint directory")
        .opt("workers", "2", "worker threads per frequency, per shard")
        .opt("shards", "1",
             "local serving shards; requests route by a consistent hash of \
              the series id (0 is allowed with --join: serve purely from \
              remotes)")
        .opt("join", "",
             "comma list of remote shard addresses (host:port, each a \
              running `serve --http` front-end) to splice into the ring \
              alongside the local shards")
        .opt("replicas", "1",
             "replication factor R: every key maps to R distinct shards \
              and reads are hedged at the rolling p95")
        .opt("queue-limit", "1024",
             "per-pool backpressure: queued requests beyond this are shed \
              with 429 (0 = unbounded)")
        .opt("state-dir", "",
             "persist per-series ES state under this directory (one slab \
              per frequency, survives restarts); empty = in-memory only")
        .opt("http", "",
             "also serve HTTP on this address (e.g. 127.0.0.1:8080)")
        .opt("requests", "64",
             "demo requests per frequency; 0 with --http serves until killed")
        .opt("scale", "200", "corpus scale for demo request data");
    let a = cli.parse(args)?;
    let freqs = parse_freqs(&a.get_str_list("freqs"))?;
    let joins: Vec<String> = a
        .get("join")
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect();
    // With remotes to join, zero local shards is a valid topology (a
    // pure router/front-end box); without them at least one local shard
    // must exist.
    let n_shards = if joins.is_empty() {
        a.get_usize("shards")?.max(1)
    } else {
        a.get_usize("shards")?
    };
    let opts = ServiceOptions {
        workers: a.get_usize("workers")?.max(1),
        queue_limit: a.get_usize("queue-limit")?,
        state_dir: match a.get("state-dir") {
            "" => None,
            dir => Some(PathBuf::from(dir)),
        },
        ..Default::default()
    };

    // Load (or init) each frequency's weights once; every local shard
    // serves a clone of the same state. A pure-remote topology loads
    // nothing — the weights live on the peers.
    let mut states: Vec<(Frequency, ModelState)> = Vec::new();
    if n_shards > 0 {
        for &freq in &freqs {
            let state = match find_checkpoint(a.get("checkpoint-dir"), freq) {
                Some(path) => {
                    let state =
                        checkpoint::load_model_state_for(&path, freq.name())?;
                    println!("[{}] serving weights from {}", freq.name(),
                             path.display());
                    state
                }
                None => {
                    // Fresh weights still exercise the full serving path.
                    let backend = backend_from_args(&a)?;
                    println!("[{}] no checkpoint in {} — serving fresh \
                              weights",
                             freq.name(), a.get("checkpoint-dir"));
                    ModelState::init(backend.as_ref(), freq.name(), 42)?
                }
            };
            states.push((freq, state));
        }
    }

    let backend_name = a.get("backend").to_string();
    let artifacts = PathBuf::from(a.get("artifacts"));
    let sharded = ShardedStack::new();
    for s in 0..n_shards {
        // Series state lives per ring segment: each local shard gets
        // its own slab subdirectory so two pools never contend for one
        // file.
        let mut shard_opts = opts.clone();
        if let Some(dir) = &opts.state_dir {
            shard_opts.state_dir = Some(dir.join(format!("shard-{s}")));
        }
        let mut stack = ServingStack::new();
        for (freq, state) in &states {
            let (bn, art) = (backend_name.clone(), artifacts.clone());
            stack.start_pool(
                Arc::new(move || backend_with_artifacts(&bn, Some(&art))),
                *freq, state.clone(), shard_opts.clone())?;
        }
        sharded.add_shard(&format!("shard-{s}"), stack)?;
    }
    for addr in &joins {
        let remote = RemoteShard::connect(addr, RemoteOptions::default())
            .with_context(|| format!("joining remote shard {addr}"))?;
        sharded.add_remote_shard(&format!("remote-{addr}"), remote)?;
        println!("joined remote shard {addr}");
    }
    let replicas = a.get_usize("replicas")?.max(1);
    sharded.set_replicas(replicas);
    let sharded = Arc::new(sharded);
    println!("{} local shard(s) + {} remote(s) × {} worker(s)/frequency, \
              queue limit {}, replication R={}",
             n_shards, joins.len(), opts.workers, opts.queue_limit,
             replicas);
    let n_req = a.get_usize("requests")?;
    let scale = a.get_usize("scale")?;

    if !a.get("http").is_empty() {
        let server = HttpServer::start_sharded(Arc::clone(&sharded),
                                               a.get("http"))?;
        let addr = server.addr().to_string();
        println!("HTTP front-end on http://{addr}  \
                  (POST /v1/series/{{id}}/observe · \
                  GET /v1/series/{{id}}/forecast · \
                  GET /v1/series/{{id}}/state · \
                  POST /v1/forecast [deprecated] · GET /v1/stats · \
                  GET /v1/metrics · GET /v1/healthz · POST /v1/reload)");
        if n_req == 0 {
            loop {
                std::thread::park(); // serve until killed
            }
        }
        for &freq in &freqs {
            http_demo(&addr, freq, n_req, scale)?;
        }
        let (code, body) =
            http::http_request(&addr, "GET", "/v1/stats", None)?;
        println!("\nGET /v1/stats → {code}\n{body}");
        return Ok(());
    }

    for &freq in &freqs {
        channel_demo(&sharded, freq, n_req, scale)?;
    }
    Ok(())
}

/// Demo request series for one frequency (only those long enough).
fn demo_series(freq: Frequency, scale: usize)
               -> Result<(NetworkConfig, Vec<data::Series>)> {
    let net = NetworkConfig::for_freq(freq)?;
    let corpus = data::generate(&GenOptions {
        scale,
        seed: 7,
        freqs: Some(vec![freq]),
    })?;
    let candidates: Vec<data::Series> = corpus
        .series
        .into_iter()
        .filter(|s| s.len() >= net.length)
        .collect();
    if candidates.is_empty() {
        bail!("no {} demo series survive the length cut at scale {scale} — \
               lower --scale", freq.name());
    }
    Ok((net, candidates))
}

/// Drive one frequency through the real HTTP wire on a single
/// keep-alive connection: POST forecasts, report throughput, then
/// exercise the stateful lane (observe → stateful forecast → state).
fn http_demo(addr: &str, freq: Frequency, n_req: usize, scale: usize)
             -> Result<()> {
    let (net, candidates) = demo_series(freq, scale)?;
    let mut client = http::HttpClient::connect(addr)?;
    let t0 = std::time::Instant::now();
    let mut ok = 0usize;
    for i in 0..n_req {
        let s = &candidates[i % candidates.len()];
        let body = api::ForecastRequest {
            freq: Some(freq),
            id: Some(s.id.clone()),
            category: Some(s.category),
            values: s.values.clone(),
        }
        .to_json()
        .to_string();
        let reply = client.request("POST", "/v1/forecast", Some(&body))?;
        if reply.code == 200
            && api::ForecastResponse::from_json(&Json::parse(&reply.body)?)?
                .forecast
                .len()
                == net.horizon
        {
            ok += 1;
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    println!("[{}] HTTP keep-alive: {ok}/{n_req} ok in {secs:.3}s \
              ({:.1} req/s)",
             freq.name(), ok as f64 / secs);

    // Stateful lane: feed one series' history as observations, then
    // forecast from the stored state — no history on the wire.
    let s = &candidates[0];
    let observe = api::ObserveRequest {
        freq: Some(freq),
        values: s.values.clone(),
        t0: None,
    }
    .to_json()
    .to_string();
    let path = format!("/v1/series/{}/observe", s.id);
    let reply = client.request("POST", &path, Some(&observe))?;
    if reply.code != 200 {
        bail!("POST {path} → HTTP {}: {}", reply.code, reply.body);
    }
    let obs = api::ObserveResponse::from_json(&Json::parse(&reply.body)?)?;
    let path = format!("/v1/series/{}/forecast?freq={}", s.id, freq.name());
    let reply = client.request("GET", &path, None)?;
    if reply.code != 200 {
        bail!("GET {path} → HTTP {}: {}", reply.code, reply.body);
    }
    let fc = api::ForecastResponse::from_json(&Json::parse(&reply.body)?)?;
    println!("    stateful `{}`: observed {} → {:?}", obs.id, obs.observed,
             &fc.forecast[..4.min(fc.forecast.len())]);
    Ok(())
}

/// `ttop`-style live dashboard: poll a running front-end's
/// `/v1/metrics`, redraw in place. One keep-alive connection, no server
/// cooperation beyond the scrape endpoint.
fn cmd_top(args: &[String]) -> Result<()> {
    let cli = Cli::new("top", "live dashboard over a serving front-end's \
                               /v1/metrics")
        .opt("url", "http://127.0.0.1:8080",
             "base URL of the serving front-end")
        .opt("interval-ms", "1000",
             "refresh interval in milliseconds (min 100)")
        .opt("iterations", "0",
             "refreshes before exiting (0 = run until killed)");
    let a = cli.parse(args)?;
    let addr = a
        .get("url")
        .trim_start_matches("http://")
        .trim_end_matches('/')
        .to_string();
    let interval = std::time::Duration::from_millis(
        a.get_usize("interval-ms")?.max(100) as u64);
    let iterations = a.get_usize("iterations")?;
    let mut client = http::HttpClient::connect(&addr)?;
    let mut prev: Option<(std::time::Instant, Vec<Sample>)> = None;
    let mut frames = 0usize;
    loop {
        let reply = client.request("GET", "/v1/metrics", None)?;
        if reply.code != 200 {
            bail!("GET /v1/metrics → HTTP {}", reply.code);
        }
        let samples = promtext::parse(&reply.body)?;
        let now = std::time::Instant::now();
        let frame = render_top(
            &addr,
            &samples,
            prev.as_ref().map(|(t, s)| {
                (now.duration_since(*t).as_secs_f64(), s.as_slice())
            }),
        );
        {
            use std::io::Write as _;
            let mut out = std::io::stdout();
            let _ = out.write_all(frame.as_bytes());
            let _ = out.flush();
        }
        prev = Some((now, samples));
        frames += 1;
        if iterations != 0 && frames >= iterations {
            return Ok(());
        }
        std::thread::sleep(interval);
    }
}

/// Render one dashboard frame: a row per `{shard, freq}` pool plus a
/// front-end footer. `prev` is `(elapsed seconds, previous scrape)` and
/// enables the shed-rate column from the second frame on.
fn render_top(addr: &str, samples: &[Sample],
              prev: Option<(f64, &[Sample])>) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    // ANSI clear screen + home cursor: redraw in place like `top`.
    out.push_str("\x1b[2J\x1b[H");
    let _ = writeln!(out, "fast-esrnn top — {addr}");
    let _ = writeln!(
        out,
        "{:<10} {:<10} {:>6} {:>6} {:>10} {:>8} {:>8} {:>8} {:>8} {:>9} \
         {:>8}",
        "SHARD", "FREQ", "DEPTH", "LIMIT", "ACCEPTED", "SHED/S", "P50MS",
        "P95MS", "P99MS", "OBSERVES", "SERIES");
    // Every bound pool exposes fesrnn_queue_accepted_total, so its
    // {shard, freq} pairs enumerate the rows.
    let mut keys: Vec<(String, String)> = samples
        .iter()
        .filter(|s| s.name == "fesrnn_queue_accepted_total")
        .filter_map(|s| {
            Some((s.label("shard")?.to_string(),
                  s.label("freq")?.to_string()))
        })
        .collect();
    keys.sort();
    keys.dedup();
    for (shard, freq) in &keys {
        let l = [("shard", shard.as_str()), ("freq", freq.as_str())];
        let val = |name| promtext::value(samples, name, &l);
        let shed = val("fesrnn_queue_shed_total");
        let shed_rate = match prev {
            Some((dt, old)) if dt > 0.0 => {
                let before =
                    promtext::value(old, "fesrnn_queue_shed_total", &l);
                (shed - before).max(0.0) / dt
            }
            _ => 0.0,
        };
        let quant = |q| {
            1e3 * promtext::histogram_quantile(
                samples, "fesrnn_request_total_seconds", &l, q)
        };
        let _ = writeln!(
            out,
            "{:<10} {:<10} {:>6} {:>6} {:>10} {:>8.1} {:>8.2} {:>8.2} \
             {:>8.2} {:>9} {:>8}",
            shard, freq,
            val("fesrnn_queue_depth") as u64,
            val("fesrnn_queue_limit") as u64,
            val("fesrnn_queue_accepted_total") as u64,
            shed_rate, quant(0.50), quant(0.95), quant(0.99),
            val("fesrnn_observe_requests_total") as u64,
            val("fesrnn_state_series") as u64);
    }
    let conns =
        promtext::value(samples, "fesrnn_http_connections_total", &[]);
    let sheds = promtext::value(samples, "fesrnn_http_sheds_total",
                                &[("kind", "backlog_full")])
        + promtext::value(samples, "fesrnn_http_sheds_total",
                          &[("kind", "stale_in_backlog")]);
    let rotations = promtext::value(
        samples, "fesrnn_http_keepalive_rotations_total", &[]);
    let deprecated = promtext::value(
        samples, "fesrnn_http_deprecated_requests_total", &[]);
    let _ = writeln!(
        out,
        "connections {conns:.0} · http sheds {sheds:.0} · keep-alive \
         rotations {rotations:.0} · legacy-path requests {deprecated:.0}");
    // Distributed footer, only when remote shards are in the ring.
    // `promtext::value` matches one exact label set, and the remote
    // families carry {shard, addr} — sum the samples by name instead.
    let sum = |name: &str| -> f64 {
        samples
            .iter()
            .filter(|s| s.name == name)
            .map(|s| s.value)
            .sum()
    };
    // Stateful-serving footer: state-store footprint plus the forecast
    // cache's hit economy, summed over {shard, freq} pools.
    let observes = sum("fesrnn_observe_requests_total");
    if observes > 0.0 {
        let _ = writeln!(
            out,
            "observes {observes:.0} (stale {:.0} · fan-outs {:.0}, errors \
             {:.0}) · state {:.0} series / {:.0} KiB · forecast cache \
             {:.0} hits / {:.0} misses / {:.0} invalidations",
            sum("fesrnn_observe_stale_total"),
            sum("fesrnn_observe_fanout_total"),
            sum("fesrnn_observe_fanout_errors_total"),
            sum("fesrnn_state_series"),
            sum("fesrnn_state_bytes") / 1024.0,
            sum("fesrnn_state_cache_hits_total"),
            sum("fesrnn_state_cache_misses_total"),
            sum("fesrnn_state_cache_invalidations_total"));
    }
    let inflight = sum("fesrnn_remote_inflight");
    let remotes = samples
        .iter()
        .filter(|s| s.name == "fesrnn_remote_inflight")
        .count();
    if remotes > 0 {
        let _ = writeln!(
            out,
            "remotes {remotes} · in-flight {inflight:.0} · hedges \
             {:.0} (wins {:.0}) · probe failures {:.0} · ejections {:.0}",
            sum("fesrnn_remote_hedges_total"),
            sum("fesrnn_remote_hedge_wins_total"),
            sum("fesrnn_remote_probe_failures_total"),
            sum("fesrnn_remote_ejections_total"));
    }
    out
}

/// Drive one frequency's pools through the in-process sharded router:
/// burst submit, await all, print stats including latency percentiles.
fn channel_demo(stack: &ShardedStack, freq: Frequency, n_req: usize,
                scale: usize) -> Result<()> {
    let (net, candidates) = demo_series(freq, scale)?;
    let t0 = std::time::Instant::now();
    let mut receivers = Vec::with_capacity(n_req);
    let mut shed = 0usize;
    for i in 0..n_req {
        let s = &candidates[i % candidates.len()];
        let req = ForecastRequest {
            id: s.id.clone(),
            values: s.values.clone(),
            category: s.category,
        };
        match stack.submit(freq, req) {
            Ok(rx) => receivers.push(rx),
            // A burst bigger than --queue-limit is *supposed* to shed
            // the excess — count it instead of aborting the demo.
            Err(e) if e.is::<QueueFull>() => shed += 1,
            Err(e) => return Err(e),
        }
    }
    let mut ok = 0usize;
    for rx in receivers {
        let resp = rx.recv()??;
        if resp.forecast.len() == net.horizon {
            ok += 1;
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    let st = stack.stats(freq)?;
    println!("[{}] served {ok}/{n_req} ({shed} shed by backpressure) in \
              {secs:.3}s ({:.1} req/s; {} batches, {} padded slots, \
              {} workers, generation {})",
             freq.name(), ok as f64 / secs, st.batches, st.padded_slots,
             st.workers, st.generation);
    println!("    queue p50 {:.2}ms p95 {:.2}ms | exec p50 {:.2}ms \
              p95 {:.2}ms | total p99 {:.2}ms",
             st.queue_wait.p50 * 1e3, st.queue_wait.p95 * 1e3,
             st.execute.p50 * 1e3, st.execute.p95 * 1e3, st.total.p99 * 1e3);
    let s = &candidates[0];
    let resp = stack.forecast(freq, ForecastRequest {
        id: s.id.clone(),
        values: s.values.clone(),
        category: Category::Other,
    })?;
    println!("    example `{}` → {:?}", resp.id,
             &resp.forecast[..4.min(resp.forecast.len())]);
    Ok(())
}
