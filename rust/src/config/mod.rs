//! Configuration: frequencies, network shapes (paper Table 1), training
//! hyper-parameters.
//!
//! The *compile-time* shapes (seasonality, horizon, window, length, hidden,
//! dilations) are authoritative in `python/compile/configs.py` and travel to
//! Rust via the artifact manifest; this module mirrors them for components
//! that run before/without an engine (data pipeline, baselines) and asserts
//! the mirror matches the manifest at engine start-up.

use anyhow::{bail, Context, Result};

use crate::runtime::FreqManifest;

/// Number of RNN window positions for a (length, input_window) pair:
/// `P = C - in + 1`, as a checked computation — errors (instead of
/// underflowing) when the series is shorter than the window. Shared by
/// [`NetworkConfig`] and the native compute core's `Shape` so the guard
/// logic cannot drift between them.
pub fn window_positions(length: usize, input_window: usize) -> Result<usize> {
    match (length + 1).checked_sub(input_window) {
        Some(p) if p > 0 => Ok(p),
        _ => bail!("length {length} is shorter than the input window \
                    {input_window} — no RNN positions exist"),
    }
}

/// Loss-bearing window positions: `P_valid = C - in - H + 1`, checked —
/// errors when `length < input_window + horizon`.
pub fn valid_window_positions(length: usize, input_window: usize,
                              horizon: usize) -> Result<usize> {
    match (length + 1).checked_sub(input_window + horizon) {
        Some(v) if v > 0 => Ok(v),
        _ => bail!("length {length} is shorter than input window \
                    {input_window} + horizon {horizon} — no loss-bearing \
                    positions exist"),
    }
}

/// Series sampling frequency. Yearly/Quarterly/Monthly have full model
/// support (the paper's scope); Weekly/Daily/Hourly exist for the data
/// pipeline and classical baselines (paper §8.5 future work).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Frequency {
    Yearly,
    Quarterly,
    Monthly,
    Weekly,
    Daily,
    Hourly,
}

pub const MODELED_FREQS: [Frequency; 3] =
    [Frequency::Yearly, Frequency::Quarterly, Frequency::Monthly];

pub const ALL_FREQS: [Frequency; 6] = [
    Frequency::Yearly, Frequency::Quarterly, Frequency::Monthly,
    Frequency::Weekly, Frequency::Daily, Frequency::Hourly,
];

impl Frequency {
    pub fn name(&self) -> &'static str {
        match self {
            Frequency::Yearly => "yearly",
            Frequency::Quarterly => "quarterly",
            Frequency::Monthly => "monthly",
            Frequency::Weekly => "weekly",
            Frequency::Daily => "daily",
            Frequency::Hourly => "hourly",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "yearly" => Frequency::Yearly,
            "quarterly" => Frequency::Quarterly,
            "monthly" => Frequency::Monthly,
            "weekly" => Frequency::Weekly,
            "daily" => Frequency::Daily,
            "hourly" => Frequency::Hourly,
            other => bail!("unknown frequency `{other}`"),
        })
    }

    /// Natural seasonal period (M4 convention).
    pub fn seasonality(&self) -> usize {
        match self {
            Frequency::Yearly => 1,
            Frequency::Quarterly => 4,
            Frequency::Monthly => 12,
            Frequency::Weekly => 52,
            Frequency::Daily => 7,
            Frequency::Hourly => 24,
        }
    }

    /// M4 forecast horizon.
    pub fn horizon(&self) -> usize {
        match self {
            Frequency::Yearly => 6,
            Frequency::Quarterly => 8,
            Frequency::Monthly => 18,
            Frequency::Weekly => 13,
            Frequency::Daily => 14,
            Frequency::Hourly => 48,
        }
    }

    /// Whether ES-RNN artifacts exist for this frequency. The paper's
    /// core scope is Y/Q/M; Daily (§8.5) and Hourly (§8.2) are built as
    /// extensions. Weekly remains future work.
    pub fn is_modeled(&self) -> bool {
        !matches!(self, Frequency::Weekly)
    }
}

/// M4 sampling category (Table 2 columns). The one-hot of this value is
/// concatenated to every RNN input window (paper §5.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Category {
    Demographic,
    Finance,
    Industry,
    Macro,
    Micro,
    Other,
}

pub const ALL_CATEGORIES: [Category; 6] = [
    Category::Demographic, Category::Finance, Category::Industry,
    Category::Macro, Category::Micro, Category::Other,
];

impl Category {
    pub fn name(&self) -> &'static str {
        match self {
            Category::Demographic => "Demographic",
            Category::Finance => "Finance",
            Category::Industry => "Industry",
            Category::Macro => "Macro",
            Category::Micro => "Micro",
            Category::Other => "Other",
        }
    }

    /// Position in [`ALL_CATEGORIES`] (the one-hot slot). A match rather
    /// than `position().unwrap()`: the compiler now proves exhaustiveness
    /// instead of the array search proving it at runtime.
    pub fn index(&self) -> usize {
        match self {
            Category::Demographic => 0,
            Category::Finance => 1,
            Category::Industry => 2,
            Category::Macro => 3,
            Category::Micro => 4,
            Category::Other => 5,
        }
    }

    pub fn from_index(i: usize) -> Result<Self> {
        ALL_CATEGORIES
            .get(i)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("category index {i} out of range"))
    }

    pub fn parse(s: &str) -> Result<Self> {
        ALL_CATEGORIES
            .iter()
            .find(|c| c.name().eq_ignore_ascii_case(s))
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unknown category `{s}`"))
    }
}

/// Mirror of Table 1 + §5.2: the network/equalization shape per frequency.
/// Must agree with `python/compile/configs.py` (checked by
/// [`NetworkConfig::check_manifest`] at startup and by unit tests).
#[derive(Debug, Clone)]
pub struct NetworkConfig {
    pub freq: Frequency,
    pub seasonality: usize,
    /// §8.2 second multiplicative seasonality (0 = single).
    pub seasonality2: usize,
    pub horizon: usize,
    pub input_window: usize,
    pub length: usize,
    pub hidden: usize,
    pub dilations: Vec<Vec<usize>>,
}

impl NetworkConfig {
    pub fn for_freq(freq: Frequency) -> Result<Self> {
        let cfg = match freq {
            Frequency::Yearly => Self {
                freq, seasonality: 1, seasonality2: 0, horizon: 6,
                input_window: 4, length: 24, hidden: 30,
                dilations: vec![vec![1, 2], vec![2, 6]],
            },
            Frequency::Quarterly => Self {
                freq, seasonality: 4, seasonality2: 0, horizon: 8,
                input_window: 8, length: 72, hidden: 40,
                dilations: vec![vec![1, 2], vec![4, 8]],
            },
            Frequency::Monthly => Self {
                freq, seasonality: 12, seasonality2: 0, horizon: 18,
                input_window: 12, length: 72, hidden: 50,
                dilations: vec![vec![1, 3], vec![6, 12]],
            },
            // §8.5: daily shares the quarterly/monthly structure.
            Frequency::Daily => Self {
                freq, seasonality: 7, seasonality2: 0, horizon: 14,
                input_window: 14, length: 140, hidden: 40,
                dilations: vec![vec![1, 2], vec![4, 8]],
            },
            // §8.2: hourly with dual 24h/168h seasonality.
            Frequency::Hourly => Self {
                freq, seasonality: 24, seasonality2: 168, horizon: 48,
                input_window: 24, length: 336, hidden: 40,
                dilations: vec![vec![1, 4], vec![24, 48]],
            },
            other => bail!("no ES-RNN network config for {other:?} \
                            (weekly is §8.5 future work)"),
        };
        Ok(cfg)
    }

    /// Number of RNN window positions (the last is forecast-only).
    ///
    /// Errors (instead of underflowing) when the equalized length is
    /// shorter than the input window.
    pub fn positions(&self) -> Result<usize> {
        window_positions(self.length, self.input_window)
            .with_context(|| format!("{:?} config", self.freq))
    }

    /// Positions with a full in-sample target (loss-bearing).
    ///
    /// Errors (instead of underflowing) when
    /// `length < input_window + horizon`.
    pub fn valid_positions(&self) -> Result<usize> {
        valid_window_positions(self.length, self.input_window, self.horizon)
            .with_context(|| format!("{:?} config", self.freq))
    }

    /// Minimum raw series length usable for training: equalized length
    /// plus validation and test holdouts (paper Eq. 8).
    pub fn min_series_length(&self) -> usize {
        self.length + 2 * self.horizon
    }

    /// Per-series Holt-Winters parameter count: the paper's `2 + S`
    /// (alpha, gamma, S initial seasonality values); dual-seasonality
    /// configs add gamma2 and the second period's initial values.
    pub fn per_series_param_count(&self) -> usize {
        if self.seasonality2 > 0 {
            3 + self.seasonality + self.seasonality2
        } else {
            2 + self.seasonality
        }
    }

    /// Width of the per-series seasonality parameter block.
    pub fn total_seasonality(&self) -> usize {
        self.seasonality + self.seasonality2
    }

    /// §8.2 dual-seasonality mode.
    pub fn dual(&self) -> bool {
        self.seasonality2 > 0
    }

    /// Assert this mirror matches what the artifacts were compiled with.
    pub fn check_manifest(&self, m: &FreqManifest) -> Result<()> {
        let ok = self.seasonality == m.seasonality
            && self.seasonality2 == m.seasonality2
            && self.horizon == m.horizon
            && self.input_window == m.input_window
            && self.length == m.length
            && self.hidden == m.hidden
            && self.dilations == m.dilations;
        if !ok {
            bail!("NetworkConfig for {:?} disagrees with artifact manifest: \
                   rust={self:?} manifest={m:?} — re-run `make artifacts` or \
                   update config/mod.rs to match configs.py", self.freq);
        }
        Ok(())
    }
}

/// Training-loop hyper-parameters (owned by Rust; not baked in artifacts).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Manifest model key override (e.g. "quarterly_pen" for the §8.4
    /// penalties ablation); None = the frequency's own name.
    pub model_key: Option<String>,
    pub epochs: usize,
    pub batch_size: usize,
    pub learning_rate: f32,
    /// Multiply the LR by this at each epoch in `lr_drop_epochs`.
    pub lr_decay: f32,
    pub lr_drop_epochs: Vec<usize>,
    /// Stop early after this many epochs without val-sMAPE improvement.
    pub patience: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            model_key: None,
            epochs: 15, // the paper reports run-times for 15 epochs
            batch_size: 64,
            learning_rate: 1e-3,
            lr_decay: 0.5,
            lr_drop_epochs: vec![7, 12],
            patience: 5,
            seed: 42,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper Table 1: dilations and LSTM sizes.
    #[test]
    fn table1_network_parameters() {
        let m = NetworkConfig::for_freq(Frequency::Monthly).unwrap();
        assert_eq!(m.dilations, vec![vec![1, 3], vec![6, 12]]);
        assert_eq!(m.hidden, 50);
        let q = NetworkConfig::for_freq(Frequency::Quarterly).unwrap();
        assert_eq!(q.dilations, vec![vec![1, 2], vec![4, 8]]);
        assert_eq!(q.hidden, 40);
        let y = NetworkConfig::for_freq(Frequency::Yearly).unwrap();
        assert_eq!(y.dilations, vec![vec![1, 2], vec![2, 6]]);
        assert_eq!(y.hidden, 30);
    }

    /// Paper §5.2: C = 72 for quarterly and monthly.
    #[test]
    fn series_length_equalization_thresholds() {
        assert_eq!(NetworkConfig::for_freq(Frequency::Quarterly).unwrap().length, 72);
        assert_eq!(NetworkConfig::for_freq(Frequency::Monthly).unwrap().length, 72);
    }

    /// Paper §3.3: N series store N * (2 + S) Holt-Winters parameters.
    #[test]
    fn per_series_param_counts() {
        assert_eq!(NetworkConfig::for_freq(Frequency::Monthly).unwrap()
                   .per_series_param_count(), 14);
        assert_eq!(NetworkConfig::for_freq(Frequency::Quarterly).unwrap()
                   .per_series_param_count(), 6);
        assert_eq!(NetworkConfig::for_freq(Frequency::Yearly).unwrap()
                   .per_series_param_count(), 3);
    }

    #[test]
    fn m4_horizons_and_seasonality() {
        assert_eq!(Frequency::Yearly.horizon(), 6);
        assert_eq!(Frequency::Quarterly.horizon(), 8);
        assert_eq!(Frequency::Monthly.horizon(), 18);
        assert_eq!(Frequency::Monthly.seasonality(), 12);
        assert_eq!(Frequency::Hourly.seasonality(), 24);
    }

    #[test]
    fn unmodeled_freqs_have_no_network() {
        assert!(NetworkConfig::for_freq(Frequency::Weekly).is_err());
        assert!(!Frequency::Weekly.is_modeled());
    }

    /// §8.2: hourly dual-seasonality shape.
    #[test]
    fn hourly_dual_seasonality_config() {
        let h = NetworkConfig::for_freq(Frequency::Hourly).unwrap();
        assert_eq!((h.seasonality, h.seasonality2), (24, 168));
        assert!(h.dual());
        assert_eq!(h.total_seasonality(), 192);
        // alpha + gamma1 + gamma2 + 24 + 168 initial values
        assert_eq!(h.per_series_param_count(), 195);
        let d = NetworkConfig::for_freq(Frequency::Daily).unwrap();
        assert!(!d.dual());
        assert_eq!(d.per_series_param_count(), 9);
    }

    #[test]
    fn parse_roundtrip() {
        for f in ALL_FREQS {
            assert_eq!(Frequency::parse(f.name()).unwrap(), f);
        }
        for c in ALL_CATEGORIES {
            assert_eq!(Category::parse(c.name()).unwrap(), c);
            assert_eq!(Category::from_index(c.index()).unwrap(), c);
        }
    }

    #[test]
    fn positions_match_python() {
        // Mirrors configs.py properties: P = C - in + 1.
        let m = NetworkConfig::for_freq(Frequency::Monthly).unwrap();
        assert_eq!(m.positions().unwrap(), 61);
        assert_eq!(m.valid_positions().unwrap(), 43);
        let y = NetworkConfig::for_freq(Frequency::Yearly).unwrap();
        assert_eq!(y.positions().unwrap(), 21);
        assert_eq!(y.valid_positions().unwrap(), 15);
    }

    #[test]
    fn degenerate_lengths_error_instead_of_underflowing() {
        // length < input_window: no positions at all.
        let mut cfg = NetworkConfig::for_freq(Frequency::Quarterly).unwrap();
        cfg.length = 4; // input_window is 8
        assert!(cfg.positions().is_err());
        assert!(cfg.valid_positions().is_err());
        // length ≥ input_window but < input_window + horizon.
        cfg.length = 10; // horizon is 8
        assert!(cfg.positions().is_ok());
        let err = cfg.valid_positions().unwrap_err();
        assert!(format!("{err:#}").contains("horizon"),
                "error should be descriptive: {err:#}");
    }
}
