#!/usr/bin/env bash
# CI perf gate: run the quick benches, record the lane-vs-scalar speedup
# trajectory, and fail on regression.
#
#   scripts/bench_gate.sh [out.json]
#
# Runs `micro_hotpath` (and `table5_speedup`) in quick mode, writes the
# scalar-vs-lane per-frequency summary to BENCH_3.json (or the given
# path), then compares the measured max speedup against the committed
# baseline (benches/bench3_baseline.json): the gate fails when the
# vectorized train step regresses more than 10% below the baseline
# speedup. The ratio is measured scalar-vs-lane on the same machine in
# the same process, so it is stable across runner hardware generations
# in a way absolute ns/step numbers are not.
set -euo pipefail

out="${1:-BENCH_3.json}"
baseline="benches/bench3_baseline.json"

export FAST_ESRNN_QUICK=1
FAST_ESRNN_BENCH_JSON="$out" cargo bench --bench micro_hotpath
cargo bench --bench table5_speedup

python3 - "$out" "$baseline" <<'EOF'
import json, sys

out_path, baseline_path = sys.argv[1], sys.argv[2]
with open(out_path) as f:
    result = json.load(f)
with open(baseline_path) as f:
    baseline = json.load(f)

got = result["max_speedup"]
want = baseline["min_speedup"]
floor = want * 0.9
per_freq_floor = baseline.get("per_freq_floor", 0.0)
print(f"lane-vs-scalar max speedup: {got:.2f}x "
      f"({result['max_speedup_freq']}); baseline {want:.2f}x, "
      f"gate floor {floor:.2f}x, per-frequency floor {per_freq_floor:.2f}x")
failed = False
for freq, row in sorted(result["frequencies"].items()):
    print(f"  {freq:<10} b{int(row['batch']):<4} "
          f"scalar {row['scalar_ns_per_step']/1e6:9.2f} ms/step   "
          f"lanes {row['lanes_ns_per_step']/1e6:9.2f} ms/step   "
          f"{row['speedup']:.2f}x")
    # A regression confined to one frequency must not hide behind the max.
    if row["speedup"] < per_freq_floor:
        print(f"FAIL: {freq} lane path fell below the per-frequency floor: "
              f"{row['speedup']:.2f}x < {per_freq_floor:.2f}x")
        failed = True
if got < floor:
    print(f"FAIL: vectorized path regressed: {got:.2f}x < {floor:.2f}x")
    failed = True
if failed:
    sys.exit(1)
print("perf gate OK")
EOF
