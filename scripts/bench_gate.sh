#!/usr/bin/env bash
# CI perf gate: run the quick benches, record the speedup trajectories,
# and fail on regression.
#
#   scripts/bench_gate.sh [bench3_out.json] [bench4_out.json] [bench5_out.json] [bench6_out.json] [bench8_out.json] [bench9_out.json] [bench10_out.json]
#
# Seven gates, all measured as same-machine ratios (stable across runner
# hardware generations in a way absolute numbers are not):
#
# * BENCH_3 — `micro_hotpath` (and `table5_speedup`) in quick mode:
#   scalar vs lane-vectorized ns/step per frequency; fails when the
#   vectorized train step regresses more than 10% below
#   benches/bench3_baseline.json.
# * BENCH_4 — `serving_throughput`: requests/sec of the N-worker forecast
#   pool over the single-worker service; fails when the pool speedup
#   drops more than 10% below benches/bench4_baseline.json.
# * BENCH_5 — `http_throughput`: keep-alive vs connection-per-request
#   req/s on the HTTP front-end, and sharded-vs-single-stack p95; fails
#   when the keep-alive speedup drops more than 10% below
#   benches/bench5_baseline.json or sharding blows up tail latency.
# * BENCH_6 — `micro_hotpath` steady-state section: persistent-pool vs
#   spawn-per-call ns/step per frequency plus allocations/step and
#   spawns/step from the counting allocator; fails when the pooled
#   speedup drops more than 10% below benches/bench6_baseline.json or
#   when any frequency's steady-state step allocates or spawns at all.
# * BENCH_8 — `http_throughput` scrape-overhead section: forecast p95
#   with a 10 Hz `GET /v1/metrics` scraper running vs without; fails
#   when the p95 overhead ratio exceeds the cap in
#   benches/bench8_baseline.json (a scrape must never stall serving).
# * BENCH_9 — `http_throughput` hedged-reads section: forecast p99 on a
#   3-shard ring with one 50 ms-delayed replica, hedged (R=2) vs
#   unhedged (R=1); fails when the hedged p99 speedup drops more than
#   10% below benches/bench9_baseline.json (hedging must keep rescuing
#   the tail).
# * BENCH_10 — `http_throughput` stateful-series section: observe
#   throughput on `POST /v1/series/{id}/observe` plus the stateful
#   forecast read p95 pure vs under a 50% observe mix; fails when the
#   mix inflates the read p95 past the cap in
#   benches/bench10_baseline.json (cache invalidation must stay cheap)
#   or observe throughput collapses relative to reads.
#
# Every cargo invocation is --locked: the committed Cargo.lock is the
# only dependency resolution CI may use.
set -euo pipefail

out="${1:-BENCH_3.json}"
out4="${2:-BENCH_4.json}"
out5="${3:-BENCH_5.json}"
out6="${4:-BENCH_6.json}"
out8="${5:-BENCH_8.json}"
out9="${6:-BENCH_9.json}"
out10="${7:-BENCH_10.json}"
baseline="benches/bench3_baseline.json"
baseline4="benches/bench4_baseline.json"
baseline5="benches/bench5_baseline.json"
baseline6="benches/bench6_baseline.json"
baseline8="benches/bench8_baseline.json"
baseline9="benches/bench9_baseline.json"
baseline10="benches/bench10_baseline.json"

export FAST_ESRNN_QUICK=1
FAST_ESRNN_BENCH_JSON="$out" FAST_ESRNN_BENCH6_JSON="$out6" \
    cargo bench --locked --bench micro_hotpath
cargo bench --locked --bench table5_speedup
FAST_ESRNN_BENCH_JSON="$out4" cargo bench --locked --bench serving_throughput
FAST_ESRNN_BENCH_JSON="$out5" FAST_ESRNN_BENCH8_JSON="$out8" \
    FAST_ESRNN_BENCH9_JSON="$out9" FAST_ESRNN_BENCH10_JSON="$out10" \
    cargo bench --locked --bench http_throughput

python3 - "$out" "$baseline" <<'EOF'
import json, sys

out_path, baseline_path = sys.argv[1], sys.argv[2]
with open(out_path) as f:
    result = json.load(f)
with open(baseline_path) as f:
    baseline = json.load(f)

got = result["max_speedup"]
want = baseline["min_speedup"]
floor = want * 0.9
per_freq_floor = baseline.get("per_freq_floor", 0.0)
print(f"lane-vs-scalar max speedup: {got:.2f}x "
      f"({result['max_speedup_freq']}); baseline {want:.2f}x, "
      f"gate floor {floor:.2f}x, per-frequency floor {per_freq_floor:.2f}x")
failed = False
for freq, row in sorted(result["frequencies"].items()):
    print(f"  {freq:<10} b{int(row['batch']):<4} "
          f"scalar {row['scalar_ns_per_step']/1e6:9.2f} ms/step   "
          f"lanes {row['lanes_ns_per_step']/1e6:9.2f} ms/step   "
          f"{row['speedup']:.2f}x")
    # A regression confined to one frequency must not hide behind the max.
    if row["speedup"] < per_freq_floor:
        print(f"FAIL: {freq} lane path fell below the per-frequency floor: "
              f"{row['speedup']:.2f}x < {per_freq_floor:.2f}x")
        failed = True
if got < floor:
    print(f"FAIL: vectorized path regressed: {got:.2f}x < {floor:.2f}x")
    failed = True
if failed:
    sys.exit(1)
print("perf gate OK")
EOF

python3 - "$out4" "$baseline4" <<'EOF'
import json, sys

out_path, baseline_path = sys.argv[1], sys.argv[2]
with open(out_path) as f:
    result = json.load(f)
with open(baseline_path) as f:
    baseline = json.load(f)

got = result["pool_speedup"]
want = baseline["min_pool_speedup"]
floor = want * 0.9
single, pool = result["single"], result["pool"]
print(f"serving pool speedup: {got:.2f}x requests/sec "
      f"({int(pool['workers'])} workers {pool['rps']:.1f} rps "
      f"p95 {pool['p95_ms']:.2f} ms vs 1 worker {single['rps']:.1f} rps "
      f"p95 {single['p95_ms']:.2f} ms); "
      f"baseline {want:.2f}x, gate floor {floor:.2f}x")
if got < floor:
    print(f"FAIL: worker pool regressed: {got:.2f}x < {floor:.2f}x")
    sys.exit(1)
print("serving gate OK")
EOF

python3 - "$out5" "$baseline5" <<'EOF'
import json, sys

out_path, baseline_path = sys.argv[1], sys.argv[2]
with open(out_path) as f:
    result = json.load(f)
with open(baseline_path) as f:
    baseline = json.load(f)

wire, fc = result["wire"], result["forecast"]
got = wire["keepalive_speedup"]
want = baseline["min_keepalive_speedup"]
floor = want * 0.9
print(f"HTTP keep-alive speedup (wire, GET /v1/healthz): {got:.2f}x "
      f"({wire['per_conn_rps']:.0f} -> {wire['keepalive_rps']:.0f} req/s); "
      f"baseline {want:.2f}x, gate floor {floor:.2f}x")
print(f"  forecast endpoint: {fc['keepalive_speedup']:.2f}x "
      f"({fc['per_conn_rps']:.0f} -> {fc['keepalive_rps']:.0f} req/s, "
      f"informational)")
single, sharded = result["single"], result["sharded"]
ratio = result["sharded_p95_ratio"]
max_ratio = baseline.get("max_sharded_p95_ratio", 0.0)
print(f"  sharding: single 1x{int(single['workers'])} "
      f"{single['rps']:.0f} req/s p95 {single['p95_ms']:.2f} ms vs "
      f"sharded {int(sharded['shards'])}x1 {sharded['rps']:.0f} req/s "
      f"p95 {sharded['p95_ms']:.2f} ms (ratio {ratio:.2f}, "
      f"cap {max_ratio:.2f})")
failed = False
if got < floor:
    print(f"FAIL: keep-alive throughput regressed: {got:.2f}x < "
          f"{floor:.2f}x connection-per-request")
    failed = True
if max_ratio > 0 and ratio > max_ratio:
    print(f"FAIL: sharded p95 is {ratio:.2f}x the single-stack p95 "
          f"(cap {max_ratio:.2f}x) — shard routing is hurting tail latency")
    failed = True
if failed:
    sys.exit(1)
print("http gate OK")
EOF

python3 - "$out6" "$baseline6" <<'EOF'
import json, sys

out_path, baseline_path = sys.argv[1], sys.argv[2]
with open(out_path) as f:
    result = json.load(f)
with open(baseline_path) as f:
    baseline = json.load(f)

got = result["max_pooled_speedup"]
want = baseline["min_pooled_speedup"]
floor = want * 0.9
print(f"pooled-vs-spawn max train-step speedup: {got:.2f}x "
      f"({int(result['pool_threads'])} pool threads); "
      f"baseline {want:.2f}x, gate floor {floor:.2f}x")
failed = False
for freq, row in sorted(result["frequencies"].items()):
    print(f"  {freq:<10} b{int(row['batch']):<4} "
          f"spawn {row['spawn_ns_per_step']/1e6:9.2f} ms/step   "
          f"pooled {row['pooled_ns_per_step']/1e6:9.2f} ms/step   "
          f"{row['pooled_speedup']:.2f}x   "
          f"allocs/step {row['allocs_per_step']:.1f}   "
          f"spawns/step {row['spawns_per_step']:.1f}")
    # The zero-cost invariants are absolute: one stray allocation per
    # step means a pooled buffer is growing again.
    if row["allocs_per_step"] != 0:
        print(f"FAIL: {freq} steady-state step allocates "
              f"({row['allocs_per_step']:.1f}/step, want 0)")
        failed = True
    if row["spawns_per_step"] != 0:
        print(f"FAIL: {freq} steady-state step spawns threads "
              f"({row['spawns_per_step']:.1f}/step, want 0)")
        failed = True
if got < floor:
    print(f"FAIL: persistent pool regressed: {got:.2f}x < {floor:.2f}x")
    failed = True
if failed:
    sys.exit(1)
print("steady-state gate OK")
EOF

python3 - "$out8" "$baseline8" <<'EOF'
import json, sys

out_path, baseline_path = sys.argv[1], sys.argv[2]
with open(out_path) as f:
    result = json.load(f)
with open(baseline_path) as f:
    baseline = json.load(f)

base, scraped = result["baseline"], result["scraped"]
ratio = result["p95_overhead_ratio"]
cap = baseline["max_p95_overhead_ratio"]
print(f"metrics scrape overhead: forecast p95 {base['p95_ms']:.2f} ms "
      f"alone vs {scraped['p95_ms']:.2f} ms with a 10 Hz /v1/metrics "
      f"scraper ({int(scraped['scrapes'])} scrapes); "
      f"ratio {ratio:.2f}, cap {cap:.2f}")
print(f"  throughput: {base['rps']:.0f} -> {scraped['rps']:.0f} req/s")
if ratio > cap:
    print(f"FAIL: scraping inflates forecast p95 {ratio:.2f}x "
          f"(cap {cap:.2f}x) — the registry render is blocking serving")
    sys.exit(1)
print("observability gate OK")
EOF

python3 - "$out9" "$baseline9" <<'EOF'
import json, sys

out_path, baseline_path = sys.argv[1], sys.argv[2]
with open(out_path) as f:
    result = json.load(f)
with open(baseline_path) as f:
    baseline = json.load(f)

un, he = result["unhedged"], result["hedged"]
got = result["hedge_p99_speedup"]
want = baseline["min_hedge_p99_speedup"]
floor = want * 0.9
print(f"hedged-read p99 rescue ({result['delay_ms']:.0f} ms slow replica): "
      f"{got:.2f}x (unhedged p99 {un['p99_ms']:.2f} ms -> hedged "
      f"{he['p99_ms']:.2f} ms, {int(he['hedges'])} hedges, "
      f"{int(he['hedge_wins'])} wins); "
      f"baseline {want:.2f}x, gate floor {floor:.2f}x")
print(f"  p50: {un['p50_ms']:.2f} -> {he['p50_ms']:.2f} ms   "
      f"p95: {un['p95_ms']:.2f} -> {he['p95_ms']:.2f} ms   "
      f"throughput: {un['rps']:.0f} -> {he['rps']:.0f} req/s")
if got < floor:
    print(f"FAIL: hedging stopped rescuing the tail: {got:.2f}x < "
          f"{floor:.2f}x — one slow replica is a p99 cliff again")
    sys.exit(1)
print("hedging gate OK")
EOF

python3 - "$out10" "$baseline10" <<'EOF'
import json, sys

out_path, baseline_path = sys.argv[1], sys.argv[2]
with open(out_path) as f:
    result = json.load(f)
with open(baseline_path) as f:
    baseline = json.load(f)

obs = result["observe"]
pure, mixed = result["forecast_pure"], result["forecast_mixed"]
ratio = result["mixed_p95_ratio"]
cap = baseline["max_mixed_p95_ratio"]
obs_ratio = result["observe_rps_ratio"]
want = baseline["min_observe_rps_ratio"]
floor = want * 0.9
print(f"stateful series routes ({int(result['series'])} series, "
      f"{int(result['threads'])} clients): observe {obs['rps']:.0f} "
      f"req/s, pure forecast {pure['rps']:.0f} req/s "
      f"p95 {pure['p95_ms']:.2f} ms, 50% observe mix "
      f"p95 {mixed['p95_ms']:.2f} ms "
      f"({int(mixed['observes'])} observes interleaved)")
print(f"  mixed/pure read p95 ratio {ratio:.2f} (cap {cap:.2f}); "
      f"observe/read rps ratio {obs_ratio:.2f} "
      f"(baseline {want:.2f}, gate floor {floor:.2f})")
failed = False
# Cap is absolute (bench8-style): invalidation churn inflating the
# read tail past the cap is a regression regardless of machine speed.
if ratio > cap:
    print(f"FAIL: observe mix inflates stateful read p95 {ratio:.2f}x "
          f"(cap {cap:.2f}x) — cache invalidation is blocking reads")
    failed = True
if obs_ratio < floor:
    print(f"FAIL: observe throughput collapsed to {obs_ratio:.2f}x the "
          f"read rate (floor {floor:.2f}x) — the state-store write "
          f"path is too slow")
    failed = True
if failed:
    sys.exit(1)
print("stateful gate OK")
EOF
