#!/usr/bin/env bash
# CI perf gate: run the quick benches, record the speedup trajectories,
# and fail on regression.
#
#   scripts/bench_gate.sh [bench3_out.json] [bench4_out.json]
#
# Two gates, both measured as same-machine ratios (stable across runner
# hardware generations in a way absolute numbers are not):
#
# * BENCH_3 — `micro_hotpath` (and `table5_speedup`) in quick mode:
#   scalar vs lane-vectorized ns/step per frequency; fails when the
#   vectorized train step regresses more than 10% below
#   benches/bench3_baseline.json.
# * BENCH_4 — `serving_throughput`: requests/sec of the N-worker forecast
#   pool over the single-worker service; fails when the pool speedup
#   drops more than 10% below benches/bench4_baseline.json.
set -euo pipefail

out="${1:-BENCH_3.json}"
out4="${2:-BENCH_4.json}"
baseline="benches/bench3_baseline.json"
baseline4="benches/bench4_baseline.json"

export FAST_ESRNN_QUICK=1
FAST_ESRNN_BENCH_JSON="$out" cargo bench --bench micro_hotpath
cargo bench --bench table5_speedup
FAST_ESRNN_BENCH_JSON="$out4" cargo bench --bench serving_throughput

python3 - "$out" "$baseline" <<'EOF'
import json, sys

out_path, baseline_path = sys.argv[1], sys.argv[2]
with open(out_path) as f:
    result = json.load(f)
with open(baseline_path) as f:
    baseline = json.load(f)

got = result["max_speedup"]
want = baseline["min_speedup"]
floor = want * 0.9
per_freq_floor = baseline.get("per_freq_floor", 0.0)
print(f"lane-vs-scalar max speedup: {got:.2f}x "
      f"({result['max_speedup_freq']}); baseline {want:.2f}x, "
      f"gate floor {floor:.2f}x, per-frequency floor {per_freq_floor:.2f}x")
failed = False
for freq, row in sorted(result["frequencies"].items()):
    print(f"  {freq:<10} b{int(row['batch']):<4} "
          f"scalar {row['scalar_ns_per_step']/1e6:9.2f} ms/step   "
          f"lanes {row['lanes_ns_per_step']/1e6:9.2f} ms/step   "
          f"{row['speedup']:.2f}x")
    # A regression confined to one frequency must not hide behind the max.
    if row["speedup"] < per_freq_floor:
        print(f"FAIL: {freq} lane path fell below the per-frequency floor: "
              f"{row['speedup']:.2f}x < {per_freq_floor:.2f}x")
        failed = True
if got < floor:
    print(f"FAIL: vectorized path regressed: {got:.2f}x < {floor:.2f}x")
    failed = True
if failed:
    sys.exit(1)
print("perf gate OK")
EOF

python3 - "$out4" "$baseline4" <<'EOF'
import json, sys

out_path, baseline_path = sys.argv[1], sys.argv[2]
with open(out_path) as f:
    result = json.load(f)
with open(baseline_path) as f:
    baseline = json.load(f)

got = result["pool_speedup"]
want = baseline["min_pool_speedup"]
floor = want * 0.9
single, pool = result["single"], result["pool"]
print(f"serving pool speedup: {got:.2f}x requests/sec "
      f"({int(pool['workers'])} workers {pool['rps']:.1f} rps "
      f"p95 {pool['p95_ms']:.2f} ms vs 1 worker {single['rps']:.1f} rps "
      f"p95 {single['p95_ms']:.2f} ms); "
      f"baseline {want:.2f}x, gate floor {floor:.2f}x")
if got < floor:
    print(f"FAIL: worker pool regressed: {got:.2f}x < {floor:.2f}x")
    sys.exit(1)
print("serving gate OK")
EOF
