#!/usr/bin/env bash
# Static-analysis gate: fesrnn-lint self-tests, then a full-tree scan.
#
#   scripts/lint_gate.sh [report-file]
#
# Runs the zero-dependency repo linter (tools/lint) as a required CI
# job. The self-test suite first proves every rule still trips on its
# embedded fixtures (a linter that silently stopped detecting anything
# would pass an empty scan); the tree scan then enforces R1..R7 on the
# real sources. The violation report is written to the given file
# (default LINT_REPORT.txt) so CI can upload it as an artifact even on
# failure.
set -euo pipefail

report="${1:-LINT_REPORT.txt}"

echo "== fesrnn-lint self-tests (fixtures must trip every rule) =="
cargo test -q --locked -p fesrnn-lint

echo "== fesrnn-lint full-tree scan =="
cargo run -q --locked -p fesrnn-lint -- --report "$report"
