#!/usr/bin/env bash
# Run one named test filter and fail if it matched nothing.
#
#   scripts/run_named_tests.sh <test-target> <name-filter>
#
# `cargo test` exits 0 when a name filter matches no tests, so a renamed
# or feature-gated suite would silently stop running. This wrapper also
# asserts that at least one test actually ran, turning that silent skip
# into a CI failure. Used by .github/workflows/ci.yml for the hourly
# dual-seasonality suite and the SIMD lane/scalar equivalence suite.
set -euo pipefail

if [ "$#" -ne 2 ]; then
  echo "usage: $0 <test-target> <name-filter>" >&2
  exit 2
fi

target="$1"
filter="$2"

if ! out=$(cargo test -q --locked --test "$target" "$filter" 2>&1); then
  echo "$out"
  exit 1
fi
echo "$out"
echo "$out" | grep -Eq "test result: ok\. [1-9][0-9]* passed" \
  || { echo "ERROR: filter '$filter' matched no tests in --test $target"; exit 1; }
