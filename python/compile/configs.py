"""Per-frequency ES-RNN configurations (paper Table 1 + §5.2).

These are the *compile-time* configs: every shape baked into an AOT artifact
comes from here. The Rust coordinator reads the same values back out of
``artifacts/manifest.json`` — it never re-derives them.

Paper mapping:
  * Table 1  — ``dilations`` / ``hidden`` per frequency.
  * §5.2     — ``length`` (series-length equalization; 72 for Q/M, 24 for Y).
  * §3.1     — ``seasonality`` (Holt-Winters period; yearly is non-seasonal,
               see §7/§8.2 of the paper).
  * M4 rules — ``horizon`` (6 / 8 / 18).
  * §3.1     — ``input_window`` chosen per Smyl's heuristic: one seasonal
               period, floored at 4.
"""

from dataclasses import dataclass, field
from typing import Tuple

N_CATEGORIES = 6  # Demographic, Finance, Industry, Macro, Micro, Other

# Smyl trained against the 0.48 quantile (slightly under the median) —
# pinball loss per Takeuchi et al. (2006), paper §3.5.
PINBALL_TAU = 0.48

# Per-series smoothing parameters learn on a faster clock than the shared
# RNN weights (Smyl's per-series learning-rate trick).
PER_SERIES_LR_MULT = 1.5


@dataclass(frozen=True)
class FreqConfig:
    """Everything needed to trace one frequency's compute graph."""

    name: str
    seasonality: int          # S: Holt-Winters period (1 = non-seasonal)
    horizon: int              # H: forecast length (M4 rules)
    input_window: int         # input window fed to the RNN at each position
    length: int               # C: equalized series length (paper §5.2)
    hidden: int               # LSTM hidden size (Table 1)
    dilations: Tuple[Tuple[int, ...], ...]  # residual blocks of dilated LSTMs
    # §8.2 second multiplicative seasonality (0 = single); hourly uses
    # 24- and 168-hour cycles per Gould et al. (2008).
    seasonality2: int = 0
    # §8.4 penalties (0.0 = off; ablation benches switch them on)
    level_penalty: float = 0.0
    cstate_penalty: float = 0.0

    @property
    def positions(self) -> int:
        """Number of RNN window positions P (last one is forecast-only)."""
        return self.length - self.input_window + 1

    @property
    def valid_positions(self) -> int:
        """Positions with a full in-sample target window (loss-bearing)."""
        return self.length - self.input_window - self.horizon + 1

    @property
    def seasonal(self) -> bool:
        return self.seasonality > 1

    @property
    def dual(self) -> bool:
        """§8.2 multiple-seasonality mode."""
        return self.seasonality2 > 0

    @property
    def total_seasonality(self) -> int:
        """Width of the per-series seasonality parameter block."""
        return self.seasonality + self.seasonality2

    @property
    def rnn_input_dim(self) -> int:
        return self.input_window + N_CATEGORIES

    @property
    def flat_dilations(self) -> Tuple[int, ...]:
        return tuple(d for block in self.dilations for d in block)


CONFIGS = {
    "yearly": FreqConfig(
        name="yearly", seasonality=1, horizon=6, input_window=4,
        length=24, hidden=30, dilations=((1, 2), (2, 6)),
    ),
    "quarterly": FreqConfig(
        name="quarterly", seasonality=4, horizon=8, input_window=8,
        length=72, hidden=40, dilations=((1, 2), (4, 8)),
    ),
    "monthly": FreqConfig(
        name="monthly", seasonality=12, horizon=18, input_window=12,
        length=72, hidden=50, dilations=((1, 3), (6, 12)),
    ),
    # §8.5: daily shares the quarterly/monthly structure (paper Fig. 3 note).
    "daily": FreqConfig(
        name="daily", seasonality=7, horizon=14, input_window=14,
        length=140, hidden=40, dilations=((1, 2), (4, 8)),
    ),
    # §8.2: hourly with dual 24h/168h multiplicative seasonality.
    "hourly": FreqConfig(
        name="hourly", seasonality=24, horizon=48, input_window=24,
        length=336, hidden=40, dilations=((1, 4), (24, 48)),
        seasonality2=168,
    ),
    # §8.4 ablation variant: quarterly with the level-variability and
    # c-state stabilization penalties enabled.
    "quarterly_pen": FreqConfig(
        name="quarterly_pen", seasonality=4, horizon=8, input_window=8,
        length=72, hidden=40, dilations=((1, 2), (4, 8)),
        level_penalty=0.05, cstate_penalty=0.05,
    ),
}

# Batch sizes we AOT-compile artifacts for. B=1 is the "per-series CPU"
# baseline of Table 5; the sweep reproduces the paper's vectorization
# speedup curve.
BATCH_SIZES = (1, 16, 64, 256)

# Per-frequency overrides (small corpora / ablation-only variants don't
# need the full sweep).
BATCH_SIZES_OVERRIDE = {
    "hourly": (1, 4),
    "daily": (1, 16, 64),
    "quarterly_pen": (64,),
}


def batch_sizes_for(freq: str, default=BATCH_SIZES):
    return BATCH_SIZES_OVERRIDE.get(freq, default)

# Default Adam hyper-parameters baked into the train_step artifact.
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
