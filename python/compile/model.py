"""Layer 2: the full ES-RNN compute graph (paper §3), in JAX.

Everything the PyTorch implementation did per training iteration is traced
here into ONE jitted function per (frequency, batch-size):

  ``train_step``:  batch → ES pre-processing (Pallas kernel) → window
      normalization/deseasonalization (Fig. 2) → dilated-residual LSTM stack
      (Table 1, Fig. 1) → tanh non-linear layer → linear adapter →
      masked pinball loss (§3.5) → gradients → Adam update of BOTH the
      shared RNN weights and the per-series Holt-Winters parameters
      (the joint training that is the heart of ES-RNN).

  ``predict``:     batch → same forward → take the last window position →
      re-seasonalize / de-normalize (§3.4) → forecasts in data space.

  ``init``:        PRNG key → initialized RNN weights (so Rust never needs
      to know initialization schemes; per-series parameters are initialized
      Rust-side from the classical Holt-Winters primer, §3.3).

The per-series parameters are *batch-dim tensor slices* here — exactly the
paper's vectorization trick. The Rust coordinator owns the N-series store
and gathers/scatters the batch slices around each step.

``use_pallas=False`` swaps every kernel for its jnp reference; the AOT
pipeline can emit both variants for A/B testing.
"""

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from . import configs
from .configs import FreqConfig, N_CATEGORIES, PINBALL_TAU, PER_SERIES_LR_MULT
from . import kernels
from .kernels import ref

EPS = 1e-8


# --------------------------------------------------------------------------
# Parameter initialization
# --------------------------------------------------------------------------

def layer_dims(cfg: FreqConfig) -> Tuple[Tuple[int, int], ...]:
    """(input_dim, hidden) per LSTM layer in stack order."""
    dims = []
    d_in = cfg.rnn_input_dim
    for _ in cfg.flat_dilations:
        dims.append((d_in, cfg.hidden))
        d_in = cfg.hidden
    return tuple(dims)


def init_rnn_params(key, cfg: FreqConfig) -> Dict[str, Any]:
    """Glorot-uniform weights for the LSTM stack + output head."""

    def glorot(key, shape):
        fan_in, fan_out = shape[0], shape[1]
        lim = jnp.sqrt(6.0 / (fan_in + fan_out))
        return jax.random.uniform(key, shape, jnp.float32, -lim, lim)

    n_layers = len(cfg.flat_dilations)
    keys = jax.random.split(key, n_layers + 2)
    cells = []
    for li, (din, dh) in enumerate(layer_dims(cfg)):
        cells.append({
            "w": glorot(keys[li], (din + dh, 4 * dh)),
            "b": jnp.zeros((4 * dh,), jnp.float32),
        })
    return {
        "cells": cells,
        "dense_w": glorot(keys[-2], (cfg.hidden, cfg.hidden)),
        "dense_b": jnp.zeros((cfg.hidden,), jnp.float32),
        "out_w": glorot(keys[-1], (cfg.hidden, cfg.horizon)),
        "out_b": jnp.zeros((cfg.horizon,), jnp.float32),
    }


def init_per_series(batch: int, cfg: FreqConfig) -> Dict[str, Any]:
    """Neutral per-series parameters (the Rust primer overwrites these).

    For §8.2 dual-seasonality configs the seasonality block packs both
    periods back-to-back (`[S1 | S2]`) and a second smoothing coefficient
    `gamma2_logit` appears.
    """
    p = {
        "alpha_logit": jnp.full((batch,), -0.5, jnp.float32),
        "gamma_logit": jnp.full((batch,), -1.0, jnp.float32),
        "log_s_init": jnp.zeros((batch, cfg.total_seasonality), jnp.float32),
    }
    if cfg.dual:
        p["gamma2_logit"] = jnp.full((batch,), -1.0, jnp.float32)
    return p


def init_opt_state(params) -> Dict[str, Any]:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros,
            "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "step": jnp.zeros((), jnp.float32)}


# --------------------------------------------------------------------------
# ES pre-processing + windowing (paper §3.1, §5.3, Fig. 2)
# --------------------------------------------------------------------------

def es_and_windows(y, cat, series, cfg: FreqConfig, use_pallas: bool):
    """Run the Holt-Winters layer and build normalized windows.

    Returns:
      feats:    [P, B, in+6]  log-normalized input windows + category one-hot
      targets:  [P, B, H]     log-normalized target windows (garbage where
                              the position mask is 0 — clamped gathers)
      pos_mask: [P]           1.0 where the full target horizon is in-sample
      levels:   [B, C]        Holt-Winters levels
      seas_ext: [B, C+H]      seasonality extended past C by tiling the
                              final period (for re-seasonalizing forecasts)
    """
    B, C = y.shape
    in_w, H, S = cfg.input_window, cfg.horizon, cfg.seasonality
    P = cfg.positions

    alpha = jax.nn.sigmoid(series["alpha_logit"])

    def tail(seas, period):
        # Seasonality beyond the filtered range wraps the final period
        # (paper §3.4).
        reps = -(-H // period)  # ceil
        return jnp.tile(seas[:, C:C + period], (1, reps))[:, :H]

    if cfg.dual:
        # §8.2: two multiplicative seasonalities (e.g. 24h and 168h).
        S1, S2 = cfg.seasonality, cfg.seasonality2
        gamma1 = jax.nn.sigmoid(series["gamma_logit"])
        gamma2 = jax.nn.sigmoid(series["gamma2_logit"])
        log_s = series["log_s_init"]
        s1_init = jnp.exp(log_s[:, :S1])
        s2_init = jnp.exp(log_s[:, S1:])
        es_fn = kernels.es_dual if use_pallas else kernels.ref_dual.es_dual_ref
        levels, seas1, seas2 = es_fn(y, alpha, gamma1, gamma2, s1_init,
                                     s2_init)
        # Combined seasonality: divide by both, one after the other
        # (Gould et al. 2008) ⇒ multiply the factors.
        seas_head = seas1[:, :C] * seas2[:, :C]
        seas_fc = tail(seas1, S1) * tail(seas2, S2)
        seas_ext = jnp.concatenate([seas_head, seas_fc], axis=1)  # [B, C+H]
    else:
        if cfg.seasonal:
            gamma = jax.nn.sigmoid(series["gamma_logit"])
            s_init = jnp.exp(series["log_s_init"])
        else:
            # Non-seasonal (yearly): pin seasonality to 1; gamma = 0 keeps
            # the recurrence at s == 1 identically, so no gradient flows.
            gamma = jnp.zeros((B,), jnp.float32)
            s_init = jnp.ones((B, S), jnp.float32)

        es_fn = kernels.es_smoothing if use_pallas else ref.es_smoothing_ref
        levels, seas = es_fn(y, alpha, gamma, s_init)    # [B,C], [B,C+S]
        seas_ext = jnp.concatenate([seas[:, :C], tail(seas, S)], axis=1)

    pos = jnp.arange(P)                                   # window p ends at
    in_idx = pos[:, None] + jnp.arange(in_w)[None, :]     # t = p+in_w (excl.)
    tgt_idx = pos[:, None] + in_w + jnp.arange(H)[None, :]
    tgt_idx_y = jnp.minimum(tgt_idx, C - 1)               # clamp; masked out

    y_in = jnp.take(y, in_idx, axis=1)                    # [B, P, in]
    s_in = jnp.take(seas_ext, in_idx, axis=1)
    y_tg = jnp.take(y, tgt_idx_y, axis=1)                 # [B, P, H]
    s_tg = jnp.take(seas_ext, tgt_idx, axis=1)            # C+H-1 max: in range
    lvl = jnp.take(levels, in_idx[:, -1], axis=1)         # [B, P]  (= l_t)

    # Eq. 6 + log squash (Fig. 2): normalize by level, deseasonalize, log.
    x_win = jnp.log(jnp.maximum(y_in / (lvl[:, :, None] * s_in), EPS))
    z_tgt = jnp.log(jnp.maximum(y_tg / (lvl[:, :, None] * s_tg), EPS))

    cat_b = jnp.broadcast_to(cat[:, None, :], (B, P, N_CATEGORIES))
    feats = jnp.concatenate([x_win, cat_b], axis=2)       # [B, P, in+6]

    feats = jnp.transpose(feats, (1, 0, 2))               # [P, B, in+6]
    targets = jnp.transpose(z_tgt, (1, 0, 2))             # [P, B, H]
    pos_mask = (pos <= C - in_w - H).astype(jnp.float32)  # [P]
    return feats, targets, pos_mask, levels, seas_ext


# --------------------------------------------------------------------------
# Dilated-residual LSTM stack (paper §3.2, Fig. 1, Table 1)
# --------------------------------------------------------------------------

def run_rnn(rnn, x_seq, cfg: FreqConfig, use_pallas: bool):
    """Run the dilated stack over the window-position axis.

    Args:
      x_seq: [P, B, in+6].
    Returns:
      out:    [P, B, H]   per-position forecasts in normalized log space.
      c_pen:  scalar      mean squared cell state of each block's first
                          layer (paper §8.4 stabilization penalty).
    """
    P, B, _ = x_seq.shape
    dil = cfg.flat_dilations
    hid = cfg.hidden
    cell_fn = kernels.lstm_cell if use_pallas else ref.lstm_cell_ref

    # Per-layer ring buffers: slot p % d holds the state from position p-d
    # — this IS the dilation (Chang et al.): cell p consumes state p-d.
    carry0 = tuple(
        (jnp.zeros((d, B, hid), jnp.float32), jnp.zeros((d, B, hid), jnp.float32))
        for d in dil)

    block_first = []  # stack index of each block's first layer
    i = 0
    for block in cfg.dilations:
        block_first.append(i)
        i += len(block)

    def step(carry, inp):
        p, x = inp
        new_carry = list(carry)
        h_in = x
        c_pens = []
        li = 0
        for bi, block in enumerate(cfg.dilations):
            block_in = h_in
            for d in block:
                h_ring, c_ring = carry[li] if False else new_carry[li]
                slot = jnp.mod(p, d)
                h_prev = jax.lax.dynamic_index_in_dim(h_ring, slot, 0, False)
                c_prev = jax.lax.dynamic_index_in_dim(c_ring, slot, 0, False)
                h_new, c_new = cell_fn(h_in, h_prev, c_prev,
                                       rnn["cells"][li]["w"],
                                       rnn["cells"][li]["b"])
                new_carry[li] = (
                    jax.lax.dynamic_update_index_in_dim(h_ring, h_new, slot, 0),
                    jax.lax.dynamic_update_index_in_dim(c_ring, c_new, slot, 0),
                )
                if li == block_first[bi]:
                    c_pens.append(jnp.mean(c_new * c_new))
                h_in = h_new
                li += 1
            if bi > 0:  # residual connection over non-first blocks (Fig. 1)
                h_in = h_in + block_in
        return tuple(new_carry), (h_in, jnp.stack(c_pens).mean())

    xs = (jnp.arange(P), x_seq)
    _, (h_seq, c_pen_seq) = jax.lax.scan(step, carry0, xs)

    # Output head (§3.4): tanh non-linear layer, then linear adapter to H.
    hidden_act = jnp.tanh(h_seq @ rnn["dense_w"] + rnn["dense_b"])
    out = hidden_act @ rnn["out_w"] + rnn["out_b"]        # [P, B, H]
    return out, jnp.mean(c_pen_seq)


# --------------------------------------------------------------------------
# Loss (paper §3.5 + §8.4 penalties)
# --------------------------------------------------------------------------

def loss_fn(params, data, cfg: FreqConfig, use_pallas: bool):
    y, cat, smask = data["y"], data["cat"], data["mask"]
    feats, targets, pos_mask, levels, _ = es_and_windows(
        y, cat, params["series"], cfg, use_pallas)
    out, c_pen = run_rnn(params["rnn"], feats, cfg, use_pallas)

    mask = pos_mask[:, None] * smask[None, :]             # [P, B]
    pin_fn = kernels.pinball_loss if use_pallas else ref.pinball_ref
    loss = pin_fn(out, targets, mask, PINBALL_TAU)

    if cfg.level_penalty > 0.0:
        # §8.4: penalize abrupt level changes → smoother forecasts.
        dlog = jnp.log(levels[:, 1:] / jnp.maximum(levels[:, :-1], EPS))
        w = smask[:, None]
        pen = jnp.sum(dlog * dlog * w) / jnp.maximum(
            jnp.sum(w) * (cfg.length - 1), 1.0)
        loss = loss + cfg.level_penalty * pen
    if cfg.cstate_penalty > 0.0:
        # §8.4: Krueger & Memisevic hidden-state stabilization.
        loss = loss + cfg.cstate_penalty * c_pen
    return loss


# --------------------------------------------------------------------------
# Train step: value+grad + Adam with per-series LR multiplier (§3.3)
# --------------------------------------------------------------------------

def _adam_update(params, grads, opt, lr):
    step = opt["step"] + 1.0
    b1, b2, eps = configs.ADAM_B1, configs.ADAM_B2, configs.ADAM_EPS
    bc1 = 1.0 - jnp.power(b1, step)
    bc2 = 1.0 - jnp.power(b2, step)

    # Per-series Holt-Winters parameters learn faster (Smyl's trick).
    mults = {
        "rnn": jax.tree_util.tree_map(lambda _: 1.0, params["rnn"]),
        "series": jax.tree_util.tree_map(
            lambda _: PER_SERIES_LR_MULT, params["series"]),
    }

    leaves_p, treedef = jax.tree_util.tree_flatten(params)
    leaves_g = treedef.flatten_up_to(grads)
    leaves_m = treedef.flatten_up_to(opt["m"])
    leaves_v = treedef.flatten_up_to(opt["v"])
    leaves_mult = treedef.flatten_up_to(mults)

    new_p, new_m, new_v = [], [], []
    for p, g, m, v, mult in zip(leaves_p, leaves_g, leaves_m, leaves_v,
                                leaves_mult):
        m2 = b1 * m + (1.0 - b1) * g
        v2 = b2 * v + (1.0 - b2) * g * g
        upd = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
        new_p.append(p - lr * mult * upd)
        new_m.append(m2)
        new_v.append(v2)

    unflat = jax.tree_util.tree_unflatten
    return unflat(treedef, new_p), {
        "m": unflat(treedef, new_m),
        "v": unflat(treedef, new_v),
        "step": step,
    }


def make_train_step(cfg: FreqConfig, use_pallas: bool = True):
    """Build the fused train step: (data, params, opt, lr) → (loss, p', o')."""

    def train_step(data, params, opt, lr):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, data, cfg, use_pallas))(params)
        new_params, new_opt = _adam_update(params, grads, opt, lr)
        return loss, new_params, new_opt

    return train_step


def make_predict(cfg: FreqConfig, use_pallas: bool = True):
    """Build the forecast fn: (data, params) → forecasts [B, H] (data space).

    Runs the RNN over every window position (state warm-up), takes the
    output at the final position t = C, then re-seasonalizes and
    de-normalizes per §3.4: ŷ = exp(out) · l_C · s_{C+1..C+H}.
    """

    def predict(data, params):
        y, cat = data["y"], data["cat"]
        C, H = cfg.length, cfg.horizon
        feats, _, _, levels, seas_ext = es_and_windows(
            y, cat, params["series"], cfg, use_pallas)
        out, _ = run_rnn(params["rnn"], feats, cfg, use_pallas)
        last = out[-1]                                    # [B, H] at t = C
        l_C = levels[:, C - 1]
        s_fc = seas_ext[:, C:C + H]
        return jnp.exp(last) * l_C[:, None] * s_fc

    return predict


def make_init(cfg: FreqConfig):
    """Build the RNN-weight initializer: (key uint32[2]) → rnn tree."""

    def init(key):
        return init_rnn_params(jax.random.wrap_key_data(key), cfg)

    return init


# --------------------------------------------------------------------------
# Spec helpers shared with aot.py and the tests
# --------------------------------------------------------------------------

def data_specs(cfg: FreqConfig, batch: int):
    f32 = jnp.float32
    return {
        "y": jax.ShapeDtypeStruct((batch, cfg.length), f32),
        "cat": jax.ShapeDtypeStruct((batch, N_CATEGORIES), f32),
        "mask": jax.ShapeDtypeStruct((batch,), f32),
    }


def param_specs(cfg: FreqConfig, batch: int):
    rnn = jax.eval_shape(lambda: init_rnn_params(jax.random.PRNGKey(0), cfg))
    series = jax.eval_shape(lambda: init_per_series(batch, cfg))
    return {"rnn": rnn, "series": series}


def opt_specs(cfg: FreqConfig, batch: int):
    p = param_specs(cfg, batch)
    return {
        "m": p,
        "v": param_specs(cfg, batch),
        "step": jax.ShapeDtypeStruct((), jnp.float32),
    }
