"""AOT pipeline: lower every ES-RNN program to HLO text + manifest.

This is the ONLY place Python touches the system: it runs once at build
time (``make artifacts``), emitting for each (frequency, batch-size):

    artifacts/{freq}_b{B}_train_step.hlo.txt
    artifacts/{freq}_b{B}_predict.hlo.txt
and per frequency:
    artifacts/{freq}_init.hlo.txt
plus a single ``artifacts/manifest.json`` describing, for every program,
the exact flattened input/output leaf order (name, shape, dtype). The Rust
coordinator is manifest-driven: it packs literals by name in manifest
order and routes outputs back to its state store by name, so Python and
Rust never need to agree on pytree internals.

Interchange is HLO *text*, not a serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 rejects;
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs, model
from .configs import CONFIGS, BATCH_SIZES, batch_sizes_for


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _path_str(prefix, path) -> str:
    parts = [prefix]
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return ".".join(p for p in parts if p)


def leaf_entries(prefix, tree):
    """Flatten a spec tree to [{name, shape, dtype}] in jax flat order."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        out.append({
            "name": _path_str(prefix, path),
            "shape": list(leaf.shape),
            "dtype": str(jnp.dtype(leaf.dtype)),
        })
    return out


def program_entry(fname, freq, batch, kind, arg_trees, out_trees):
    """Manifest record: inputs/outputs as flattened (name, shape, dtype)."""
    inputs, outputs = [], []
    for prefix, tree in arg_trees:
        inputs.extend(leaf_entries(prefix, tree))
    for prefix, tree in out_trees:
        outputs.extend(leaf_entries(prefix, tree))
    return {
        "file": fname, "freq": freq, "batch": batch, "kind": kind,
        "inputs": inputs, "outputs": outputs,
    }


def lower_train_step(cfg, batch, use_pallas):
    data = model.data_specs(cfg, batch)
    params = model.param_specs(cfg, batch)
    opt = model.opt_specs(cfg, batch)
    lr = jax.ShapeDtypeStruct((), jnp.float32)
    fn = model.make_train_step(cfg, use_pallas)
    lowered = jax.jit(fn, keep_unused=True).lower(data, params, opt, lr)
    loss_spec = jax.ShapeDtypeStruct((), jnp.float32)
    entry_io = (
        [("data", data), ("params", params), ("opt", opt), ("lr", lr)],
        [("loss", loss_spec), ("params", params), ("opt", opt)],
    )
    return to_hlo_text(lowered), entry_io


def lower_predict(cfg, batch, use_pallas):
    data = {
        "y": jax.ShapeDtypeStruct((batch, cfg.length), jnp.float32),
        "cat": jax.ShapeDtypeStruct((batch, configs.N_CATEGORIES),
                                    jnp.float32),
    }
    params = model.param_specs(cfg, batch)
    fn = model.make_predict(cfg, use_pallas)
    lowered = jax.jit(fn, keep_unused=True).lower(data, params)
    fc = jax.ShapeDtypeStruct((batch, cfg.horizon), jnp.float32)
    entry_io = (
        [("data", data), ("params", params)],
        [("forecast", fc)],
    )
    return to_hlo_text(lowered), entry_io


def lower_es(cfg, batch, use_pallas):
    """Debug/verification program: expose the raw ES layer (levels, seas).

    The Rust property tests execute this against their own pure-Rust
    Holt-Winters filter to pin the L1 kernel numerics across the AOT
    boundary (kernel ≡ jnp-ref ≡ rust mirror).
    """
    import jax.nn
    from . import kernels
    from .kernels import ref as kref

    specs = {
        "y": jax.ShapeDtypeStruct((batch, cfg.length), jnp.float32),
        "alpha_logit": jax.ShapeDtypeStruct((batch,), jnp.float32),
        "gamma_logit": jax.ShapeDtypeStruct((batch,), jnp.float32),
        "log_s_init": jax.ShapeDtypeStruct((batch, cfg.seasonality),
                                           jnp.float32),
    }

    def es_fn(d):
        alpha = jax.nn.sigmoid(d["alpha_logit"])
        if cfg.seasonal:
            gamma = jax.nn.sigmoid(d["gamma_logit"])
            s_init = jnp.exp(d["log_s_init"])
        else:
            gamma = jnp.zeros_like(d["gamma_logit"])
            s_init = jnp.ones_like(d["log_s_init"])
        fn = kernels.es_smoothing if use_pallas else kref.es_smoothing_ref
        levels, seas = fn(d["y"], alpha, gamma, s_init)
        return levels, seas

    lowered = jax.jit(es_fn, keep_unused=True).lower(specs)
    lv = jax.ShapeDtypeStruct((batch, cfg.length), jnp.float32)
    se = jax.ShapeDtypeStruct((batch, cfg.length + cfg.seasonality),
                              jnp.float32)
    entry_io = ([("data", specs)], [("levels", lv), ("seas", se)])
    return to_hlo_text(lowered), entry_io


def lower_init(cfg):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    fn = model.make_init(cfg)
    lowered = jax.jit(fn, keep_unused=True).lower(key)
    rnn_spec = jax.eval_shape(
        lambda: model.init_rnn_params(jax.random.PRNGKey(0), cfg))
    entry_io = ([("key", key)], [("rnn", rnn_spec)])
    return to_hlo_text(lowered), entry_io


def build(out_dir, freqs, batch_sizes, use_pallas=True, verbose=True):
    os.makedirs(out_dir, exist_ok=True)
    programs = {}

    def emit(name, text, entry):
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        programs[name] = entry
        if verbose:
            print(f"  wrote {fname} ({len(text) / 1e6:.2f} MB, "
                  f"{len(entry['inputs'])} in / {len(entry['outputs'])} out)")

    for freq in freqs:
        cfg = CONFIGS[freq]
        text, (ins, outs) = lower_init(cfg)
        emit(f"{freq}_init", text,
             program_entry(f"{freq}_init.hlo.txt", freq, 0, "init", ins, outs))
        if not cfg.dual:
            # ES-layer debug program (fixed B=8) for cross-layer checks.
            text, (ins, outs) = lower_es(cfg, 8, use_pallas)
            emit(f"{freq}_b8_es", text,
                 program_entry(f"{freq}_b8_es.hlo.txt", freq, 8, "es",
                               ins, outs))
        for b in batch_sizes_for(freq, batch_sizes):
            text, (ins, outs) = lower_train_step(cfg, b, use_pallas)
            emit(f"{freq}_b{b}_train_step", text,
                 program_entry(f"{freq}_b{b}_train_step.hlo.txt", freq, b,
                               "train_step", ins, outs))
            text, (ins, outs) = lower_predict(cfg, b, use_pallas)
            emit(f"{freq}_b{b}_predict", text,
                 program_entry(f"{freq}_b{b}_predict.hlo.txt", freq, b,
                               "predict", ins, outs))

    manifest = {
        "version": 1,
        "variant": "pallas" if use_pallas else "ref",
        "tau": configs.PINBALL_TAU,
        "per_series_lr_mult": configs.PER_SERIES_LR_MULT,
        "batch_sizes": list(batch_sizes),
        "configs": {
            f: {
                "seasonality": c.seasonality,
                "seasonality2": c.seasonality2,
                "horizon": c.horizon,
                "input_window": c.input_window,
                "length": c.length,
                "hidden": c.hidden,
                "dilations": [list(b) for b in c.dilations],
                "positions": c.positions,
                "valid_positions": c.valid_positions,
            }
            for f, c in CONFIGS.items() if f in freqs
        },
        "programs": programs,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(f"  wrote manifest.json ({len(programs)} programs)")
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="output directory for HLO text + manifest")
    ap.add_argument("--freqs", default=",".join(CONFIGS))
    ap.add_argument("--batch-sizes",
                    default=",".join(str(b) for b in BATCH_SIZES))
    ap.add_argument("--variant", choices=("pallas", "ref"), default="pallas")
    args = ap.parse_args()
    freqs = [f.strip() for f in args.freqs.split(",") if f.strip()]
    batch_sizes = [int(b) for b in args.batch_sizes.split(",")]
    build(args.out, freqs, batch_sizes, use_pallas=args.variant == "pallas")


if __name__ == "__main__":
    main()
