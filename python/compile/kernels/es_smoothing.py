"""Pallas kernel: batched Holt-Winters level/seasonality recurrence.

This is *the* kernel the paper is about. Smyl's original C++ implementation
ran the exponential-smoothing recurrence one series at a time on a CPU; the
paper's contribution is vectorizing it so the per-series parameters
(alpha, gamma, initial seasonality) become batch-dim tensor slices.

TPU mapping (see DESIGN.md §Hardware-Adaptation):
  * the grid iterates over batch *blocks* — each program instance owns
    ``block_b`` series, the analogue of the paper's CUDA batch parallelism;
  * the whole [block_b, C] series block plus the rolling seasonality buffer
    live in VMEM for the entire time loop — one HBM read of y, one HBM
    write of levels/seas, zero traffic inside the recurrence (the paper's
    PyTorch version re-materializes per-step tensors in HBM);
  * the time loop is a ``fori_loop`` *inside* the kernel: sequential in t,
    dense vector ops across the batch lanes.

``interpret=True`` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO. Correctness is pinned to
``ref.es_smoothing_ref`` by pytest; the backward pass differentiates the
reference (see ``custom_vjp`` below).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _pick_block_b(B: int) -> int:
    """Largest power-of-two batch block ≤ 32 that divides B.

    Multiples of the 8-sublane f32 tile granule; the §Perf sweep (see
    EXPERIMENTS.md) showed per-grid-step overhead dominates below 32 rows
    while VMEM stays ≪ 1% of budget (≈30 kB at C=72), so 32 is the sweet
    spot that still leaves ≥2 grid steps of parallelism at B=64. The B=1
    "per-series CPU" baseline falls back to the batch itself.
    """
    for cand in (32, 16, 8, 4, 2, 1):
        if B % cand == 0:
            return cand
    return 1


def _es_kernel(y_ref, alpha_ref, gamma_ref, sinit_ref, lev_ref, seas_ref,
               *, C: int, S: int, block_b: int):
    """One grid step: the full C-step recurrence for a block of series."""
    y = y_ref[...]                       # [block_b, C]   — VMEM resident
    alpha = alpha_ref[...]               # [block_b]
    gamma = gamma_ref[...]               # [block_b]
    sbuf0 = sinit_ref[...]               # [block_b, S]   — rolling s buffer

    # Emit the initial seasonality values s_0..s_{S-1} (they are trainable
    # per-series parameters and part of the output contract).
    seas_ref[:, :S] = sbuf0

    def body(t, carry):
        l_prev, sbuf = carry
        idx = jnp.mod(t, S)              # slot holding s_t
        s_t = jax.lax.dynamic_slice(sbuf, (0, idx), (block_b, 1))[:, 0]
        y_t = jax.lax.dynamic_slice(y, (0, t), (block_b, 1))[:, 0]
        # Eq. 1 with the trend term removed (the RNN models trend, Eq. 5).
        l_t = jnp.where(t == 0, y_t / s_t,
                        alpha * y_t / s_t + (1.0 - alpha) * l_prev)
        # Eq. 3: seasonality update, written S steps ahead.
        s_next = gamma * y_t / l_t + (1.0 - gamma) * s_t
        pl.store(lev_ref, (slice(None), pl.dslice(t, 1)), l_t[:, None])
        pl.store(seas_ref, (slice(None), pl.dslice(t + S, 1)), s_next[:, None])
        sbuf = jax.lax.dynamic_update_slice(sbuf, s_next[:, None], (0, idx))
        return l_t, sbuf

    jax.lax.fori_loop(0, C, body, (jnp.zeros((block_b,), y.dtype), sbuf0))


def es_smoothing_pallas(y, alpha, gamma, s_init):
    """Raw Pallas forward (no autodiff). Shapes as in ``es_smoothing_ref``."""
    B, C = y.shape
    S = s_init.shape[1]
    block_b = _pick_block_b(B)
    grid = (B // block_b,)
    kernel = functools.partial(_es_kernel, C=C, S=S, block_b=block_b)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, C), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b, S), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, C), lambda i: (i, 0)),
            pl.BlockSpec((block_b, C + S), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, C), y.dtype),
            jax.ShapeDtypeStruct((B, C + S), y.dtype),
        ],
        interpret=True,
    )(y, alpha, gamma, s_init)


@jax.custom_vjp
def es_smoothing(y, alpha, gamma, s_init):
    """Differentiable ES recurrence: Pallas forward, reference-VJP backward.

    Pallas kernels do not get automatic VJPs; rather than hand-derive the
    (long) recurrence adjoint we differentiate the jnp reference, whose
    forward outputs are verified equal to the kernel's by pytest. This is
    exactly the bwd the XLA autograd would build for the same math.
    """
    return es_smoothing_pallas(y, alpha, gamma, s_init)


def _es_fwd(y, alpha, gamma, s_init):
    return es_smoothing(y, alpha, gamma, s_init), (y, alpha, gamma, s_init)


def _es_bwd(res, cts):
    _, vjp = jax.vjp(ref.es_smoothing_ref, *res)
    return vjp(cts)


es_smoothing.defvjp(_es_fwd, _es_bwd)
