"""Pallas kernel: fused LSTM cell.

The paper's deep-learning layer (Fig. 1, Table 1) is a stack of dilated
LSTMs. On GPU, PyTorch dispatches four separate gate matmuls plus a handful
of pointwise kernels per cell step. Here the whole cell is one fused kernel:

  * a single ``[B, Din+Dh] @ [Din+Dh, 4*Dh]`` matmul feeds the MXU — the
    gate weights are packed so the systolic array sees one large GEMM
    instead of four skinny ones;
  * gate nonlinearities and the state update are fused element-wise ops on
    the matmul result while it is still in VMEM.

The hidden sizes in Table 1 (30/40/50) are small relative to the 128×128
MXU tile, which the paper itself flags (§8.3: "our GPU utilization was very
low"). The kernel keeps the whole cell in one block — padding to the MXU
tile is the compiler's job; the win is fusion, not tiling.

interpret=True (CPU PJRT cannot run Mosaic); backward differentiates the
jnp reference via custom_vjp, mirroring es_smoothing.py.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _lstm_kernel(x_ref, h_ref, c_ref, w_ref, b_ref, h_out_ref, c_out_ref):
    x = x_ref[...]                               # [B, Din]
    h = h_ref[...]                               # [B, Dh]
    c = c_ref[...]                               # [B, Dh]
    w = w_ref[...]                               # [Din+Dh, 4*Dh]
    b = b_ref[...]                               # [4*Dh]
    dh = h.shape[1]
    # One fused GEMM for all four gates.
    z = jnp.concatenate([x, h], axis=1) @ w + b[None, :]
    i = z[:, 0 * dh:1 * dh]
    f = z[:, 1 * dh:2 * dh]
    g = z[:, 2 * dh:3 * dh]
    o = z[:, 3 * dh:4 * dh]
    c_new = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    h_out_ref[...] = h_new
    c_out_ref[...] = c_new


def lstm_cell_pallas(x, h, c, w, b):
    """Raw Pallas forward. Shapes as in ``ref.lstm_cell_ref``."""
    B, _ = x.shape
    dh = h.shape[1]
    return pl.pallas_call(
        _lstm_kernel,
        out_shape=[
            jax.ShapeDtypeStruct((B, dh), x.dtype),
            jax.ShapeDtypeStruct((B, dh), x.dtype),
        ],
        interpret=True,
    )(x, h, c, w, b)


@jax.custom_vjp
def lstm_cell(x, h, c, w, b):
    """Differentiable fused LSTM cell (Pallas fwd, reference-VJP bwd)."""
    h_new, c_new = lstm_cell_pallas(x, h, c, w, b)
    return h_new, c_new


def _cell_fwd(x, h, c, w, b):
    return lstm_cell(x, h, c, w, b), (x, h, c, w, b)


def _cell_bwd(res, cts):
    _, vjp = jax.vjp(ref.lstm_cell_ref, *res)
    return vjp(cts)


lstm_cell.defvjp(_cell_fwd, _cell_bwd)
