"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here, written in
the most obvious jnp style. They serve three purposes:

  1. pytest compares kernel output to these references (the core
     correctness signal for Layer 1);
  2. the kernels' ``custom_vjp`` backward passes differentiate *these*
     functions (forward = Pallas, backward = XLA-fused reference gradient —
     numerics match because forward outputs match);
  3. ``model.py`` can be traced with ``use_pallas=False`` to produce an
     all-reference HLO used for A/B testing the artifacts.
"""

import jax
import jax.numpy as jnp


def es_smoothing_ref(y, alpha, gamma, s_init):
    """Batched Holt-Winters level/seasonality recurrence (paper Eqs. 1, 3).

    Trend (Eq. 2) is intentionally absent: in ES-RNN the RNN models the
    trend (Eq. 5). Multiplicative seasonality with period S = s_init.shape[1].
    A period of S == 1 degenerates to simple exponential smoothing; pass
    gamma = 0 and s_init = 1 to keep seasonality pinned at 1.

    Args:
      y:      [B, C]   positive observations.
      alpha:  [B]      level smoothing coefficient in (0, 1).
      gamma:  [B]      seasonality smoothing coefficient in [0, 1).
      s_init: [B, S]   initial seasonality factors (positive).

    Returns:
      levels: [B, C]    l_t for t = 0..C-1 (l_0 = y_0 / s_0).
      seas:   [B, C+S]  s_t for t = 0..C+S-1 (first S entries are s_init;
                        entry t+S is produced while consuming y_t).
    """
    B, C = y.shape
    S = s_init.shape[1]

    def step(carry, t):
        l_prev, sbuf = carry                      # sbuf[:, t % S] holds s_t
        idx = jnp.mod(t, S)
        s_t = jax.lax.dynamic_slice(sbuf, (0, idx), (B, 1))[:, 0]
        y_t = jax.lax.dynamic_slice(y, (0, t), (B, 1))[:, 0]
        l_t = jnp.where(t == 0, y_t / s_t,
                        alpha * y_t / s_t + (1.0 - alpha) * l_prev)
        s_next = gamma * y_t / l_t + (1.0 - gamma) * s_t   # becomes s_{t+S}
        sbuf = jax.lax.dynamic_update_slice(sbuf, s_next[:, None], (0, idx))
        return (l_t, sbuf), (l_t, s_t, s_next)

    init = (jnp.zeros((B,), y.dtype), s_init)
    (_, _), (levels_t, seas_t, seas_next) = jax.lax.scan(
        step, init, jnp.arange(C))
    levels = jnp.transpose(levels_t)              # [B, C]
    # seas[t] for t < C comes straight from the scan; the final S entries
    # (t = C .. C+S-1) are the last S "next" values in time order.
    seas_head = jnp.transpose(seas_t)             # [B, C]
    tail_src = jnp.transpose(seas_next)           # [B, C]; entry t is s_{t+S}
    seas_tail = tail_src[:, C - S:]               # s_C .. s_{C+S-1}
    seas = jnp.concatenate([seas_head, seas_tail], axis=1)
    return levels, seas


def lstm_cell_ref(x, h, c, w, b):
    """Single fused LSTM cell with forget-gate bias 1.0.

    Args:
      x: [B, Din] input;  h, c: [B, Dh] previous state.
      w: [Din+Dh, 4*Dh] packed weights (gate order i, f, g, o).
      b: [4*Dh] packed bias.

    Returns: (h_new, c_new), each [B, Dh].
    """
    z = jnp.concatenate([x, h], axis=1) @ w + b[None, :]
    i, f, g, o = jnp.split(z, 4, axis=1)
    c_new = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return h_new, c_new


def pinball_ref(yhat, target, mask, tau):
    """Masked pinball (quantile) loss, paper §3.5.

    Args:
      yhat, target: [P, B, H] predictions / truths in normalized log space.
      mask: [P, B] 1.0 where the (position, series) pair carries loss
            (in-sample target fully observed AND series not padding).
      tau: scalar quantile in (0, 1).

    Returns: scalar mean loss over valid elements.
    """
    d = target - yhat
    per_elem = jnp.maximum(tau * d, (tau - 1.0) * d)      # [P, B, H]
    w = mask[:, :, None]
    denom = jnp.maximum(jnp.sum(w) * yhat.shape[2], 1.0)
    return jnp.sum(per_elem * w) / denom
