"""Pallas kernel: masked pinball (quantile) loss — paper §3.5.

sMAPE/MASE (the M4 metrics) are non-differentiable, so ES-RNN trains
against the pinball loss at tau = 0.48 (Takeuchi et al., 2006). The mask
zeroes both padded series (partial final batch / §8.1 variable-length
support) and window positions whose target horizon runs past the end of the
training region — the paper's "unpad and mask" step.

The kernel reduces the whole [P, B, H] tensor to a masked *sum* in one
pass; the division by the valid count happens outside (the count is cheap
and keeping the kernel a pure reduction makes it trivially tileable).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _pinball_kernel(yhat_ref, target_ref, mask_ref, out_ref, *, tau: float):
    d = target_ref[...] - yhat_ref[...]                  # [P, B, H]
    per_elem = jnp.maximum(tau * d, (tau - 1.0) * d)
    w = mask_ref[...][:, :, None]
    out_ref[0, 0] = jnp.sum(per_elem * w)


def pinball_sum_pallas(yhat, target, mask, tau: float):
    """Masked pinball *sum* over all elements; returns a [1,1] tensor."""
    kernel = functools.partial(_pinball_kernel, tau=tau)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((1, 1), yhat.dtype),
        interpret=True,
    )(yhat, target, mask)


def _pinball_mean(yhat, target, mask, tau: float):
    total = pinball_sum_pallas(yhat, target, mask, tau)[0, 0]
    denom = jnp.maximum(jnp.sum(mask) * yhat.shape[2], 1.0)
    return total / denom


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def pinball_loss(yhat, target, mask, tau):
    """Differentiable masked pinball mean (Pallas fwd, reference-VJP bwd).

    ``tau`` is static (baked into the artifact); mask carries no gradient.
    """
    return _pinball_mean(yhat, target, mask, tau)


def _pin_fwd(yhat, target, mask, tau):
    return pinball_loss(yhat, target, mask, tau), (yhat, target, mask)


def _pin_bwd(tau, res, ct):
    yhat, target, mask = res
    _, vjp = jax.vjp(lambda a, b, m: ref.pinball_ref(a, b, m, tau),
                     yhat, target, mask)
    return vjp(ct)


pinball_loss.defvjp(_pin_fwd, _pin_bwd)
