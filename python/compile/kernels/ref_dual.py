"""Pure-jnp oracle for the dual-seasonality ES recurrence (§8.2)."""

import jax
import jax.numpy as jnp


def es_dual_ref(y, alpha, gamma1, gamma2, s1_init, s2_init):
    """Reference for `es_dual` (see es_dual.py for the recurrence).

    Args:
      y: [B, C]; alpha, gamma1, gamma2: [B];
      s1_init: [B, S1]; s2_init: [B, S2].

    Returns: (levels [B, C], seas1 [B, C+S1], seas2 [B, C+S2]).
    """
    B, C = y.shape
    S1 = s1_init.shape[1]
    S2 = s2_init.shape[1]

    def step(carry, t):
        l_prev, b1, b2 = carry
        i1 = jnp.mod(t, S1)
        i2 = jnp.mod(t, S2)
        s1_t = jax.lax.dynamic_slice(b1, (0, i1), (B, 1))[:, 0]
        s2_t = jax.lax.dynamic_slice(b2, (0, i2), (B, 1))[:, 0]
        y_t = jax.lax.dynamic_slice(y, (0, t), (B, 1))[:, 0]
        denom = s1_t * s2_t
        l_t = jnp.where(t == 0, y_t / denom,
                        alpha * y_t / denom + (1.0 - alpha) * l_prev)
        s1_n = gamma1 * y_t / (l_t * s2_t) + (1.0 - gamma1) * s1_t
        s2_n = gamma2 * y_t / (l_t * s1_t) + (1.0 - gamma2) * s2_t
        b1 = jax.lax.dynamic_update_slice(b1, s1_n[:, None], (0, i1))
        b2 = jax.lax.dynamic_update_slice(b2, s2_n[:, None], (0, i2))
        return (l_t, b1, b2), (l_t, s1_t, s2_t, s1_n, s2_n)

    init = (jnp.zeros((B,), y.dtype), s1_init, s2_init)
    (_, _, _), (lev, s1_t, s2_t, s1_n, s2_n) = jax.lax.scan(
        step, init, jnp.arange(C))
    levels = jnp.transpose(lev)
    seas1 = jnp.concatenate(
        [jnp.transpose(s1_t), jnp.transpose(s1_n)[:, C - S1:]], axis=1)
    seas2 = jnp.concatenate(
        [jnp.transpose(s2_t), jnp.transpose(s2_n)[:, C - S2:]], axis=1)
    return levels, seas1, seas2
