"""Layer-1 Pallas kernels (build-time only; lowered into the L2 HLO).

Public surface:
  * ``es_smoothing``  — batched Holt-Winters recurrence (the paper's core
    vectorization target),
  * ``lstm_cell``     — fused LSTM cell for the dilated stack,
  * ``pinball_loss``  — masked surrogate training loss,
  * ``ref``           — pure-jnp oracles for all of the above.

Each kernel is wrapped in ``jax.custom_vjp``: forward runs the Pallas
kernel (interpret=True), backward differentiates the matching reference.
"""

from . import ref, ref_dual
from .es_smoothing import es_smoothing, es_smoothing_pallas
from .es_dual import es_dual, es_dual_pallas
from .lstm_cell import lstm_cell, lstm_cell_pallas
from .pinball import pinball_loss, pinball_sum_pallas

__all__ = [
    "ref", "ref_dual",
    "es_smoothing", "es_smoothing_pallas",
    "es_dual", "es_dual_pallas",
    "lstm_cell", "lstm_cell_pallas",
    "pinball_loss", "pinball_sum_pallas",
]
