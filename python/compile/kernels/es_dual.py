"""Pallas kernel: dual-seasonality Holt-Winters recurrence (paper §8.2).

Smyl's full M4 submission used multiple multiplicative seasonalities for
hourly data (24-hour and 168-hour cycles). Following Gould et al. (2008),
two seasonality buffers are maintained and the data is de-seasonalized by
both in turn:

    l_t        = α · y_t / (s1_t · s2_t) + (1 - α) · l_{t-1}
    s1_{t+S1}  = γ1 · y_t / (l_t · s2_t) + (1 - γ1) · s1_t
    s2_{t+S2}  = γ2 · y_t / (l_t · s1_t) + (1 - γ2) · s2_t

Same VMEM-resident structure as `es_smoothing`: grid over batch blocks,
whole time loop in-kernel with both rolling buffers in registers/VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref_dual
from .es_smoothing import _pick_block_b


def _es_dual_kernel(y_ref, alpha_ref, g1_ref, g2_ref, s1_ref, s2_ref,
                    lev_ref, seas1_ref, seas2_ref,
                    *, C: int, S1: int, S2: int, block_b: int):
    y = y_ref[...]                           # [block_b, C]
    alpha = alpha_ref[...]
    g1 = g1_ref[...]
    g2 = g2_ref[...]
    buf1 = s1_ref[...]                       # [block_b, S1]
    buf2 = s2_ref[...]                       # [block_b, S2]
    seas1_ref[:, :S1] = buf1
    seas2_ref[:, :S2] = buf2

    def body(t, carry):
        l_prev, b1, b2 = carry
        i1 = jnp.mod(t, S1)
        i2 = jnp.mod(t, S2)
        s1_t = jax.lax.dynamic_slice(b1, (0, i1), (block_b, 1))[:, 0]
        s2_t = jax.lax.dynamic_slice(b2, (0, i2), (block_b, 1))[:, 0]
        y_t = jax.lax.dynamic_slice(y, (0, t), (block_b, 1))[:, 0]
        denom = s1_t * s2_t
        l_t = jnp.where(t == 0, y_t / denom,
                        alpha * y_t / denom + (1.0 - alpha) * l_prev)
        s1_n = g1 * y_t / (l_t * s2_t) + (1.0 - g1) * s1_t
        s2_n = g2 * y_t / (l_t * s1_t) + (1.0 - g2) * s2_t
        pl.store(lev_ref, (slice(None), pl.dslice(t, 1)), l_t[:, None])
        pl.store(seas1_ref, (slice(None), pl.dslice(t + S1, 1)), s1_n[:, None])
        pl.store(seas2_ref, (slice(None), pl.dslice(t + S2, 1)), s2_n[:, None])
        b1 = jax.lax.dynamic_update_slice(b1, s1_n[:, None], (0, i1))
        b2 = jax.lax.dynamic_update_slice(b2, s2_n[:, None], (0, i2))
        return l_t, b1, b2

    jax.lax.fori_loop(0, C, body,
                      (jnp.zeros((block_b,), y.dtype), buf1, buf2))


def es_dual_pallas(y, alpha, gamma1, gamma2, s1_init, s2_init):
    """Raw Pallas forward. Returns (levels [B,C], seas1 [B,C+S1],
    seas2 [B,C+S2])."""
    B, C = y.shape
    S1 = s1_init.shape[1]
    S2 = s2_init.shape[1]
    block_b = _pick_block_b(B)
    grid = (B // block_b,)
    kernel = functools.partial(_es_dual_kernel, C=C, S1=S1, S2=S2,
                               block_b=block_b)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, C), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b, S1), lambda i: (i, 0)),
            pl.BlockSpec((block_b, S2), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, C), lambda i: (i, 0)),
            pl.BlockSpec((block_b, C + S1), lambda i: (i, 0)),
            pl.BlockSpec((block_b, C + S2), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, C), y.dtype),
            jax.ShapeDtypeStruct((B, C + S1), y.dtype),
            jax.ShapeDtypeStruct((B, C + S2), y.dtype),
        ],
        interpret=True,
    )(y, alpha, gamma1, gamma2, s1_init, s2_init)


@jax.custom_vjp
def es_dual(y, alpha, gamma1, gamma2, s1_init, s2_init):
    """Differentiable dual-seasonality ES (Pallas fwd, reference-VJP bwd)."""
    return es_dual_pallas(y, alpha, gamma1, gamma2, s1_init, s2_init)


def _fwd(y, alpha, gamma1, gamma2, s1_init, s2_init):
    args = (y, alpha, gamma1, gamma2, s1_init, s2_init)
    return es_dual(*args), args


def _bwd(res, cts):
    _, vjp = jax.vjp(ref_dual.es_dual_ref, *res)
    return vjp(cts)


es_dual.defvjp(_fwd, _bwd)
