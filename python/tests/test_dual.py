"""§8.2 dual-seasonality extension: kernel vs oracle, model integration."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import configs, model
from compile.kernels import es_dual, es_dual_pallas, ref_dual

settings.register_profile("dual", max_examples=15, deadline=None)
settings.load_profile("dual")


@given(st.data(), st.sampled_from([(2, 48, 4, 8), (4, 96, 24, 48),
                                   (1, 30, 3, 5)]))
def test_es_dual_matches_ref(data, shape):
    b, c, s1, s2 = shape
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    y = jnp.array(rng.uniform(1.0, 200.0, (b, c)).astype(np.float32))
    alpha = jnp.array(rng.uniform(0.05, 0.95, b).astype(np.float32))
    g1 = jnp.array(rng.uniform(0.0, 0.6, b).astype(np.float32))
    g2 = jnp.array(rng.uniform(0.0, 0.6, b).astype(np.float32))
    s1i = jnp.array(rng.uniform(0.5, 1.5, (b, s1)).astype(np.float32))
    s2i = jnp.array(rng.uniform(0.5, 1.5, (b, s2)).astype(np.float32))
    lk, sk1, sk2 = es_dual(y, alpha, g1, g2, s1i, s2i)
    lr, sr1, sr2 = ref_dual.es_dual_ref(y, alpha, g1, g2, s1i, s2i)
    np.testing.assert_allclose(lk, lr, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(sk1, sr1, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(sk2, sr2, rtol=1e-5, atol=1e-5)


def test_es_dual_shapes():
    b, c, s1, s2 = 4, 40, 4, 10
    out = es_dual_pallas(jnp.ones((b, c)), jnp.full((b,), 0.3),
                         jnp.full((b,), 0.1), jnp.full((b,), 0.1),
                         jnp.ones((b, s1)), jnp.ones((b, s2)))
    assert out[0].shape == (b, c)
    assert out[1].shape == (b, c + s1)
    assert out[2].shape == (b, c + s2)


def test_es_dual_degenerates_to_single_when_s2_is_ones():
    """With s2 ≡ 1 and gamma2 = 0, dual must equal the single recurrence."""
    from compile.kernels import ref
    b, c, s1 = 3, 36, 4
    rng = np.random.default_rng(0)
    y = jnp.array(rng.uniform(1, 100, (b, c)).astype(np.float32))
    alpha = jnp.full((b,), 0.4)
    g1 = jnp.full((b,), 0.2)
    s1i = jnp.array(rng.uniform(0.8, 1.2, (b, s1)).astype(np.float32))
    ld, sd1, _ = ref_dual.es_dual_ref(y, alpha, g1, jnp.zeros((b,)),
                                      s1i, jnp.ones((b, 2)))
    ls, ss = ref.es_smoothing_ref(y, alpha, g1, s1i)
    np.testing.assert_allclose(ld, ls, rtol=1e-5)
    np.testing.assert_allclose(sd1, ss, rtol=1e-5)


def test_es_dual_recovers_planted_dual_cycle():
    """Filter a clean dual-seasonal series with the true inits: forecast
    seasonality from both cycles should track the planted pattern."""
    b, c, s1, s2 = 1, 168 * 2, 24, 168
    t = np.arange(c)
    p1 = 1.0 + 0.3 * np.sin(2 * np.pi * t / 24)
    p2 = 1.0 + 0.15 * np.sin(2 * np.pi * t / 168)
    y = jnp.array((100.0 * p1 * p2)[None, :].astype(np.float32))
    s1i = jnp.array((1.0 + 0.3 * np.sin(2 * np.pi * np.arange(24) / 24))
                    [None, :].astype(np.float32))
    s2i = jnp.array((1.0 + 0.15 * np.sin(2 * np.pi * np.arange(168) / 168))
                    [None, :].astype(np.float32))
    lv, *_ = ref_dual.es_dual_ref(y, jnp.full((1,), 0.2), jnp.full((1,), 0.1),
                                  jnp.full((1,), 0.05), s1i, s2i)
    # level should be ~flat at 100 since both cycles are explained
    assert float(jnp.std(lv)) / float(jnp.mean(lv)) < 0.03


def test_hourly_model_trains_and_predicts():
    cfg = configs.CONFIGS["hourly"]
    assert cfg.dual and cfg.total_seasonality == 192
    b = 4
    rng = np.random.default_rng(1)
    t = np.arange(cfg.length)
    y = (100 * (1 + 0.2 * np.sin(2 * np.pi * t / 24))
         * (1 + 0.1 * np.sin(2 * np.pi * t / 168)))
    y = jnp.array((y[None] * rng.uniform(0.9, 1.1, (b, cfg.length)))
                  .astype(np.float32))
    cat = jax.nn.one_hot(jnp.arange(b) % 6, 6)
    data = {"y": y, "cat": cat, "mask": jnp.ones((b,))}
    params = {"rnn": model.init_rnn_params(jax.random.PRNGKey(0), cfg),
              "series": model.init_per_series(b, cfg)}
    assert "gamma2_logit" in params["series"]
    opt = model.init_opt_state(params)
    step = jax.jit(model.make_train_step(cfg, use_pallas=True))
    losses = []
    for _ in range(4):
        loss, params, opt = step(data, params, opt, 1e-3)
        losses.append(float(loss))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]
    fc = jax.jit(model.make_predict(cfg))({"y": y, "cat": cat}, params)
    assert fc.shape == (b, cfg.horizon)
    assert bool(jnp.all(fc > 0)) and bool(jnp.all(jnp.isfinite(fc)))


def test_penalized_variant_config():
    pen = configs.CONFIGS["quarterly_pen"]
    base = configs.CONFIGS["quarterly"]
    assert pen.level_penalty > 0 and pen.cstate_penalty > 0
    assert (pen.seasonality, pen.horizon, pen.hidden) == \
        (base.seasonality, base.horizon, base.hidden)
