"""AOT pipeline contract tests: manifest consistency and HLO emission.

These don't execute the HLO (that's the Rust side's integration tests);
they pin the manifest format and the leaf-ordering guarantees Rust relies
on.
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, configs, model
from compile.configs import CONFIGS


def test_leaf_names_are_stable_and_prefixed():
    cfg = CONFIGS["quarterly"]
    entries = aot.leaf_entries("params", model.param_specs(cfg, 4))
    names = [e["name"] for e in entries]
    assert "params.rnn.cells.0.b" in names
    assert "params.rnn.cells.0.w" in names
    assert "params.series.alpha_logit" in names
    assert "params.series.log_s_init" in names
    # dict ordering inside a pytree is sorted-by-key, hence deterministic
    assert names == sorted(names) or len(set(names)) == len(names)


def test_train_step_io_counts():
    cfg = CONFIGS["quarterly"]
    b = 4
    data = model.data_specs(cfg, b)
    params = model.param_specs(cfg, b)
    opt = model.opt_specs(cfg, b)
    n_params = len(jax.tree_util.tree_leaves(params))
    n_opt = len(jax.tree_util.tree_leaves(opt))
    n_data = len(jax.tree_util.tree_leaves(data))
    assert n_params == 15  # 12 rnn + 3 per-series
    assert n_opt == 31     # 2*15 moments + step
    assert n_data == 3
    # inputs: data + params + opt + lr ; outputs: loss + params + opt
    assert n_data + n_params + n_opt + 1 == 50
    assert 1 + n_params + n_opt == 47


def test_shapes_in_specs_match_config():
    for freq, cfg in CONFIGS.items():
        b = 8
        d = model.data_specs(cfg, b)
        assert d["y"].shape == (b, cfg.length)
        p = model.param_specs(cfg, b)
        assert p["series"]["log_s_init"].shape == (b, cfg.total_seasonality)
        assert p["rnn"]["out_w"].shape == (cfg.hidden, cfg.horizon)


@pytest.mark.slow
def test_build_emits_parseable_hlo_and_manifest(tmp_path):
    out = tmp_path / "arts"
    manifest = aot.build(str(out), ["yearly"], [1], verbose=False)
    files = os.listdir(out)
    assert "manifest.json" in files
    assert "yearly_b1_train_step.hlo.txt" in files
    assert "yearly_b8_es.hlo.txt" in files
    # manifest agrees with what's on disk
    reloaded = json.loads((out / "manifest.json").read_text())
    assert reloaded["tau"] == configs.PINBALL_TAU
    for name, prog in reloaded["programs"].items():
        path = out / prog["file"]
        assert path.exists(), name
        text = path.read_text()
        assert text.startswith("HloModule"), f"{name} is not HLO text"
        # parameter count in the entry computation matches manifest inputs
        entry = [l for l in text.splitlines() if "ENTRY" in l]
        assert entry, name
    ts = reloaded["programs"]["yearly_b1_train_step"]
    assert len(ts["inputs"]) == 50
    assert len(ts["outputs"]) == 47
    assert ts["inputs"][-1]["name"] == "lr"  # (data, params, opt, lr) order
    assert ts["outputs"][0]["name"] == "loss"


def test_program_naming_convention():
    # Rust's Manifest::program_name mirrors this format exactly.
    assert aot.program_entry("f", "monthly", 64, "train_step", [], [])["kind"] \
        == "train_step"
    cfg = CONFIGS["monthly"]
    assert cfg.positions == 61
    assert cfg.valid_positions == 43


def test_manifest_configs_match_python_configs():
    """What aot writes must equal what configs.py declares (and, by the
    Rust unit tests, what config/mod.rs mirrors)."""
    entry = {
        f: {
            "seasonality": c.seasonality,
            "horizon": c.horizon,
            "input_window": c.input_window,
            "length": c.length,
            "hidden": c.hidden,
        }
        for f, c in CONFIGS.items()
    }
    assert entry["monthly"]["hidden"] == 50   # Table 1
    assert entry["quarterly"]["hidden"] == 40
    assert entry["yearly"]["hidden"] == 30
    assert entry["monthly"]["length"] == 72   # §5.2
    assert entry["quarterly"]["length"] == 72
