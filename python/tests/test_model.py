"""L2 correctness: the full ES-RNN compute graph.

Shape contracts (Fig. 1 / Table 1), the windowing math (Fig. 2), joint
training behaviour (loss falls, per-series parameters move), and
Pallas-vs-reference parity of the whole graph.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import configs, model
from compile.configs import CONFIGS


def toy_batch(cfg, b, seed=0):
    rng = np.random.default_rng(seed)
    t = np.arange(cfg.length)
    seas = 1.0 + (0.25 * np.sin(2 * np.pi * t / cfg.seasonality)
                  if cfg.seasonal else 0.0 * t)
    base = 50.0 * (1.0 + 0.003 * t)
    y = base[None, :] * seas[None, :] * rng.uniform(0.9, 1.1, (b, cfg.length))
    cat = jax.nn.one_hot(jnp.array(rng.integers(0, 6, b)), 6)
    return {
        "y": jnp.array(y.astype(np.float32)),
        "cat": cat.astype(jnp.float32),
        "mask": jnp.ones((b,), jnp.float32),
    }


def fresh(cfg, b, seed=0):
    params = {
        "rnn": model.init_rnn_params(jax.random.PRNGKey(seed), cfg),
        "series": model.init_per_series(b, cfg),
    }
    return params, model.init_opt_state(params)


# ---------------------------------------------------------------------
# Architecture shapes (Table 1 / Fig. 1)
# ---------------------------------------------------------------------

@pytest.mark.parametrize("freq", ["yearly", "quarterly", "monthly"])
def test_rnn_parameter_shapes(freq):
    cfg = CONFIGS[freq]
    rnn = model.init_rnn_params(jax.random.PRNGKey(0), cfg)
    assert len(rnn["cells"]) == len(cfg.flat_dilations)
    din0 = cfg.input_window + configs.N_CATEGORIES
    assert rnn["cells"][0]["w"].shape == (din0 + cfg.hidden, 4 * cfg.hidden)
    for cell in rnn["cells"][1:]:
        assert cell["w"].shape == (2 * cfg.hidden, 4 * cfg.hidden)
    assert rnn["out_w"].shape == (cfg.hidden, cfg.horizon)


@pytest.mark.parametrize("freq", ["yearly", "quarterly", "monthly"])
def test_window_and_output_shapes(freq):
    cfg = CONFIGS[freq]
    b = 4
    data = toy_batch(cfg, b)
    params, _ = fresh(cfg, b)
    feats, targets, pos_mask, levels, seas_ext = model.es_and_windows(
        data["y"], data["cat"], params["series"], cfg, use_pallas=False)
    P = cfg.positions
    assert feats.shape == (P, b, cfg.rnn_input_dim)
    assert targets.shape == (P, b, cfg.horizon)
    assert pos_mask.shape == (P,)
    assert int(pos_mask.sum()) == cfg.valid_positions
    assert levels.shape == (b, cfg.length)
    assert seas_ext.shape == (b, cfg.length + cfg.horizon)
    out, c_pen = model.run_rnn(params["rnn"], feats, cfg, use_pallas=False)
    assert out.shape == (P, b, cfg.horizon)
    assert np.isfinite(float(c_pen))


def test_position_mask_boundary():
    """The last loss-bearing position's target must end exactly at C."""
    cfg = CONFIGS["quarterly"]
    P, V = cfg.positions, cfg.valid_positions
    # position p consumes target indices [p+in, p+in+H): valid iff ≤ C
    last_valid = V - 1
    assert last_valid + cfg.input_window + cfg.horizon == cfg.length
    assert P - V == cfg.horizon  # forecast-only tail positions


# ---------------------------------------------------------------------
# Fig. 2 windowing semantics
# ---------------------------------------------------------------------

def test_windows_are_log_normalized_deseasonalized():
    cfg = CONFIGS["quarterly"]
    b = 2
    data = toy_batch(cfg, b, seed=3)
    params, _ = fresh(cfg, b)
    feats, targets, _, levels, seas_ext = model.es_and_windows(
        data["y"], data["cat"], params["series"], cfg, use_pallas=False)
    # Reconstruct window p=0 by hand: x_i = log(y_i / (l_t * s_i)),
    # t = input_window - 1.
    p = 0
    t = cfg.input_window - 1
    l_t = levels[:, t]
    y_win = data["y"][:, :cfg.input_window]
    s_win = seas_ext[:, :cfg.input_window]
    expect = jnp.log(y_win / (l_t[:, None] * s_win))
    np.testing.assert_allclose(feats[p, :, :cfg.input_window], expect,
                               rtol=1e-5, atol=1e-5)
    # category one-hot rides along unscaled
    np.testing.assert_allclose(feats[p, :, cfg.input_window:], data["cat"],
                               rtol=1e-6)


def test_predict_reseasonalizes_and_denormalizes():
    """predict output must be exp(out) * level * seasonality > 0 with the
    seasonal phase of the history."""
    cfg = CONFIGS["quarterly"]
    b = 4
    data = toy_batch(cfg, b, seed=5)
    params, _ = fresh(cfg, b)
    fc = model.make_predict(cfg, use_pallas=False)(
        {"y": data["y"], "cat": data["cat"]}, params)
    assert fc.shape == (b, cfg.horizon)
    assert bool(jnp.all(fc > 0.0))
    assert bool(jnp.all(jnp.isfinite(fc)))


# ---------------------------------------------------------------------
# Joint training behaviour (§3.3)
# ---------------------------------------------------------------------

@pytest.mark.parametrize("freq", ["quarterly", "yearly"])
def test_train_step_reduces_loss_and_moves_per_series_params(freq):
    cfg = CONFIGS[freq]
    b = 8
    data = toy_batch(cfg, b, seed=1)
    params, opt = fresh(cfg, b)
    step = jax.jit(model.make_train_step(cfg, use_pallas=False))
    alpha_before = params["series"]["alpha_logit"].copy()
    losses = []
    for _ in range(12):
        loss, params, opt = step(data, params, opt, 3e-3)
        losses.append(float(loss))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0], losses
    assert float(opt["step"]) == 12.0
    # Joint training: per-series alpha logits must have moved.
    moved = jnp.abs(params["series"]["alpha_logit"] - alpha_before).max()
    assert float(moved) > 1e-5, "per-series params did not train"
    if cfg.seasonal:
        assert float(jnp.abs(opt["m"]["series"]["log_s_init"]).max()) > 0.0


def test_nonseasonal_params_receive_zero_grads():
    cfg = CONFIGS["yearly"]
    b = 4
    data = toy_batch(cfg, b, seed=2)
    params, _ = fresh(cfg, b)
    grads = jax.grad(
        lambda p: model.loss_fn(p, data, cfg, use_pallas=False))(params)
    assert float(jnp.abs(grads["series"]["gamma_logit"]).max()) == 0.0
    assert float(jnp.abs(grads["series"]["log_s_init"]).max()) == 0.0
    assert float(jnp.abs(grads["series"]["alpha_logit"]).max()) > 0.0


def test_masked_series_get_zero_param_grads():
    """§8.1 masking: padded series contribute no gradient anywhere."""
    cfg = CONFIGS["quarterly"]
    b = 4
    data = toy_batch(cfg, b, seed=4)
    data = dict(data)
    data["mask"] = jnp.array([1.0, 1.0, 0.0, 1.0])
    params, _ = fresh(cfg, b)
    grads = jax.grad(
        lambda p: model.loss_fn(p, data, cfg, use_pallas=False))(params)
    assert float(jnp.abs(grads["series"]["alpha_logit"][2])) == 0.0
    assert float(jnp.abs(grads["series"]["log_s_init"][2]).max()) == 0.0
    assert float(jnp.abs(grads["series"]["alpha_logit"][0])) > 0.0


def test_per_series_lr_multiplier_applied():
    cfg = CONFIGS["quarterly"]
    b = 4
    data = toy_batch(cfg, b, seed=6)
    params, opt = fresh(cfg, b)
    loss, p2, o2 = model.make_train_step(cfg, use_pallas=False)(
        data, params, opt, 1e-3)
    # First Adam step: update magnitude = lr * mult * sign(g) (bias-corrected
    # mhat/sqrt(vhat) = ±1 for any nonzero grad); so per-series deltas must
    # be ≈ lr * PER_SERIES_LR_MULT.
    d_alpha = jnp.abs(p2["series"]["alpha_logit"] - params["series"]["alpha_logit"])
    d_rnn = jnp.abs(p2["rnn"]["out_b"] - params["rnn"]["out_b"])
    expected_series = 1e-3 * configs.PER_SERIES_LR_MULT
    np.testing.assert_allclose(d_alpha, expected_series, rtol=1e-2)
    np.testing.assert_allclose(jnp.max(d_rnn), 1e-3, rtol=1e-2)


# ---------------------------------------------------------------------
# Pallas ≡ reference across the whole graph
# ---------------------------------------------------------------------

@pytest.mark.parametrize("freq", ["yearly", "quarterly", "monthly"])
def test_full_graph_pallas_matches_ref(freq):
    cfg = CONFIGS[freq]
    b = 8
    data = toy_batch(cfg, b, seed=7)
    params, _ = fresh(cfg, b)
    lp = model.loss_fn(params, data, cfg, use_pallas=True)
    lr_ = model.loss_fn(params, data, cfg, use_pallas=False)
    np.testing.assert_allclose(float(lp), float(lr_), rtol=1e-5)
    fp = model.make_predict(cfg, True)({"y": data["y"], "cat": data["cat"]},
                                       params)
    fr = model.make_predict(cfg, False)({"y": data["y"], "cat": data["cat"]},
                                        params)
    np.testing.assert_allclose(fp, fr, rtol=1e-4, atol=1e-4)


def test_penalties_change_loss_when_enabled():
    import dataclasses
    base = CONFIGS["quarterly"]
    cfg_pen = dataclasses.replace(base, level_penalty=0.1,
                                  cstate_penalty=0.1)
    b = 4
    data = toy_batch(base, b, seed=8)
    params, _ = fresh(base, b)
    l0 = float(model.loss_fn(params, data, base, use_pallas=False))
    l1 = float(model.loss_fn(params, data, cfg_pen, use_pallas=False))
    assert l1 > l0, "§8.4 penalties should add positive terms"


def test_dilated_state_reuse():
    """A layer with dilation d must consume state from position p - d:
    feeding an impulse at position 0 can only affect a d-dilated layer's
    recurrent path at positions ≥ d."""
    cfg = CONFIGS["quarterly"]  # dilations (1,2),(4,8)
    b = 1
    P = 12
    rnn = model.init_rnn_params(jax.random.PRNGKey(0), cfg)
    x = jnp.zeros((P, b, cfg.rnn_input_dim))
    x_imp = x.at[0].set(1.0)
    out0, _ = model.run_rnn(rnn, x, cfg, use_pallas=False)
    out1, _ = model.run_rnn(rnn, x_imp, cfg, use_pallas=False)
    diff = jnp.abs(out0 - out1).sum(axis=(1, 2))
    assert float(diff[0]) > 0.0  # feed-forward path reacts immediately
    assert float(diff[1]) > 0.0  # dilation-1 layer carries state to p=1
