"""L1 correctness: Pallas kernels vs the pure-jnp oracles.

Hypothesis sweeps shapes and values; equality here is the foundation the
whole AOT stack rests on (the kernels' custom_vjp backward differentiates
the oracle, so forward equality ⇒ consistent gradients).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (es_smoothing, es_smoothing_pallas, lstm_cell,
                             pinball_loss, pinball_sum_pallas, ref)

settings.register_profile("kernels", max_examples=25, deadline=None)
settings.load_profile("kernels")


def rng_series(data, b, c, lo=0.5, hi=500.0):
    return np.array(data.draw(
        st.lists(st.lists(st.floats(lo, hi), min_size=c, max_size=c),
                 min_size=b, max_size=b)), dtype=np.float32)


# ---------------------------------------------------------------------
# es_smoothing
# ---------------------------------------------------------------------

@given(st.data(),
       st.sampled_from([(1, 8, 1), (2, 12, 4), (8, 24, 4), (16, 72, 12),
                        (3, 30, 12), (8, 72, 4)]))
def test_es_smoothing_matches_ref(data, shape):
    b, c, s = shape
    y = rng_series(data, b, c)
    alpha = np.array(data.draw(st.lists(st.floats(0.01, 0.99), min_size=b,
                                        max_size=b)), dtype=np.float32)
    gamma = np.array(data.draw(st.lists(st.floats(0.0, 0.9), min_size=b,
                                        max_size=b)), dtype=np.float32)
    s_init = np.array(data.draw(
        st.lists(st.lists(st.floats(0.3, 3.0), min_size=s, max_size=s),
                 min_size=b, max_size=b)), dtype=np.float32)
    l_k, s_k = es_smoothing(jnp.array(y), jnp.array(alpha), jnp.array(gamma),
                            jnp.array(s_init))
    l_r, s_r = ref.es_smoothing_ref(jnp.array(y), jnp.array(alpha),
                                    jnp.array(gamma), jnp.array(s_init))
    np.testing.assert_allclose(l_k, l_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(s_k, s_r, rtol=1e-5, atol=1e-5)


def test_es_smoothing_shapes():
    b, c, s = 8, 24, 4
    y = jnp.ones((b, c))
    l, se = es_smoothing_pallas(y, jnp.full((b,), 0.3), jnp.full((b,), 0.1),
                                jnp.ones((b, s)))
    assert l.shape == (b, c)
    assert se.shape == (b, c + s)


def test_es_smoothing_constant_series_flat():
    b, c = 4, 20
    y = jnp.full((b, c), 7.0)
    l, se = es_smoothing(y, jnp.full((b,), 0.4), jnp.full((b,), 0.2),
                         jnp.ones((b, 1)))
    np.testing.assert_allclose(l, 7.0, rtol=1e-5)
    np.testing.assert_allclose(se, 1.0, rtol=1e-5)


def test_es_smoothing_gamma_zero_keeps_seasonality():
    b, c, s = 2, 16, 4
    s_init = jnp.array([[0.8, 1.1, 1.2, 0.9]] * b)
    y = jnp.ones((b, c)) * 10.0
    _, se = es_smoothing(y, jnp.full((b,), 0.5), jnp.zeros((b,)), s_init)
    # With gamma = 0, every seasonal cycle repeats s_init exactly.
    for k in range(c // s):
        np.testing.assert_allclose(se[:, k * s:(k + 1) * s], s_init,
                                   rtol=1e-6)


@given(st.data())
def test_es_smoothing_grads_match_ref(data):
    b, c, s = 4, 16, 4
    y = jnp.array(rng_series(data, b, c))
    alpha = jnp.full((b,), 0.35)
    gamma = jnp.full((b,), 0.15)
    s_init = jnp.ones((b, s))

    def loss_k(a, g, si):
        l, se = es_smoothing(y, a, g, si)
        return jnp.sum(l) + jnp.sum(se * se)

    def loss_r(a, g, si):
        l, se = ref.es_smoothing_ref(y, a, g, si)
        return jnp.sum(l) + jnp.sum(se * se)

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(alpha, gamma, s_init)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(alpha, gamma, s_init)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(a, b_, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------
# lstm_cell
# ---------------------------------------------------------------------

@given(st.data(), st.sampled_from([(1, 5, 8), (16, 18, 50), (4, 14, 40),
                                   (8, 10, 30)]))
def test_lstm_cell_matches_ref(data, shape):
    b, din, dh = shape
    key = jax.random.PRNGKey(data.draw(st.integers(0, 2**31)))
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    x = jax.random.normal(k1, (b, din))
    h = jax.random.normal(k2, (b, dh))
    c = jax.random.normal(k3, (b, dh))
    w = jax.random.normal(k4, (din + dh, 4 * dh)) * 0.2
    bias = jax.random.normal(k5, (4 * dh,)) * 0.1
    hk, ck = lstm_cell(x, h, c, w, bias)
    hr, cr = ref.lstm_cell_ref(x, h, c, w, bias)
    np.testing.assert_allclose(hk, hr, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(ck, cr, rtol=1e-5, atol=1e-6)


def test_lstm_cell_gates_bounded():
    b, din, dh = 8, 6, 12
    x = jnp.ones((b, din)) * 100.0  # saturate
    h = jnp.zeros((b, dh))
    c = jnp.zeros((b, dh))
    w = jnp.ones((din + dh, 4 * dh)) * 0.5
    bias = jnp.zeros((4 * dh,))
    hk, ck = lstm_cell(x, h, c, w, bias)
    assert bool(jnp.all(jnp.abs(hk) <= 1.0 + 1e-6))  # |tanh| * sigmoid ≤ 1
    assert bool(jnp.all(jnp.abs(ck) <= 1.0 + 1e-5))  # from zero state


@given(st.data())
def test_lstm_cell_grads_match_ref(data):
    b, din, dh = 4, 6, 10
    key = jax.random.PRNGKey(data.draw(st.integers(0, 2**31)))
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, din))
    h = jax.random.normal(ks[1], (b, dh))
    c = jax.random.normal(ks[2], (b, dh))
    w = jax.random.normal(ks[3], (din + dh, 4 * dh)) * 0.2
    bias = jax.random.normal(ks[4], (4 * dh,)) * 0.1

    def lk(w, bias):
        hh, cc = lstm_cell(x, h, c, w, bias)
        return jnp.sum(hh * hh) + jnp.sum(cc)

    def lr(w, bias):
        hh, cc = ref.lstm_cell_ref(x, h, c, w, bias)
        return jnp.sum(hh * hh) + jnp.sum(cc)

    gk = jax.grad(lk, argnums=(0, 1))(w, bias)
    gr = jax.grad(lr, argnums=(0, 1))(w, bias)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(a, b_, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------
# pinball
# ---------------------------------------------------------------------

@given(st.data(), st.sampled_from([(5, 4, 6), (43, 16, 18), (1, 1, 1),
                                   (57, 8, 8)]))
def test_pinball_matches_ref(data, shape):
    p, b, h = shape
    key = jax.random.PRNGKey(data.draw(st.integers(0, 2**31)))
    k1, k2, k3 = jax.random.split(key, 3)
    yhat = jax.random.normal(k1, (p, b, h))
    tgt = jax.random.normal(k2, (p, b, h))
    mask = (jax.random.uniform(k3, (p, b)) > 0.3).astype(jnp.float32)
    tau = data.draw(st.sampled_from([0.2, 0.48, 0.5, 0.8]))
    lk = pinball_loss(yhat, tgt, mask, tau)
    lr = ref.pinball_ref(yhat, tgt, mask, tau)
    np.testing.assert_allclose(lk, lr, rtol=1e-5, atol=1e-7)


def test_pinball_all_masked_is_zero():
    yhat = jnp.ones((3, 2, 4))
    tgt = jnp.zeros((3, 2, 4))
    mask = jnp.zeros((3, 2))
    assert float(pinball_loss(yhat, tgt, mask, 0.48)) == 0.0


def test_pinball_sum_kernel_scalar_shape():
    yhat = jnp.zeros((2, 2, 2))
    out = pinball_sum_pallas(yhat, yhat, jnp.ones((2, 2)), 0.48)
    assert out.shape == (1, 1)


def test_pinball_masked_entries_do_not_contribute():
    yhat = jnp.zeros((2, 2, 1))
    tgt = jnp.ones((2, 2, 1)) * 100.0
    # mask off the second position entirely
    m1 = jnp.array([[1.0, 1.0], [0.0, 0.0]])
    tgt2 = tgt.at[1].set(-999.0)  # garbage in masked region
    l1 = pinball_loss(yhat, tgt, m1, 0.48)
    l2 = pinball_loss(yhat, tgt2, m1, 0.48)
    np.testing.assert_allclose(l1, l2, rtol=1e-6)
