//! Quickstart: train ES-RNN on a small synthetic quarterly corpus, then
//! serve a forecast through the dynamic-batching service — the 60-second
//! tour of the public API, end-to-end on the pure-Rust native backend.
//!
//! Run with: `cargo run --release --example quickstart`
//! (no artifacts or XLA needed; set FAST_ESRNN_BACKEND=pjrt to run the
//! same flow against AOT artifacts under `--features pjrt`).

use fast_esrnn::config::{Frequency, TrainConfig};
use fast_esrnn::coordinator::{EvalSplit, Trainer};
use fast_esrnn::data::{generate, GenOptions};
use fast_esrnn::forecast::{ForecastRequest, ForecastService, ServiceOptions};
use fast_esrnn::runtime::{default_backend, Backend};

fn main() -> anyhow::Result<()> {
    // 1. Pick an execution backend (native CPU unless overridden).
    let backend = default_backend()?;
    println!("backend: {}", backend.platform());

    // 2. A small deterministic corpus (1/400 of the M4 Table 2 counts).
    let corpus = generate(&GenOptions { scale: 400, ..Default::default() })?;
    println!("corpus: {} series", corpus.len());

    // 3. Train quarterly ES-RNN for a few epochs.
    let tc = TrainConfig {
        epochs: 5,
        batch_size: 16,
        ..Default::default()
    };
    let mut trainer = Trainer::new(backend.as_ref(), Frequency::Quarterly,
                                   &corpus, tc)?;
    println!("training on {} equalized series…", trainer.series_count());
    let report = trainer.train(true)?;

    // 4. Score the test holdout and print a few forecasts.
    let test = trainer.evaluate(EvalSplit::Test)?;
    println!("\ntest sMAPE {:.3}  MASE {:.3}  ({} series, {:.1}s train)",
             test.smape, test.mase, test.count, report.train_secs);

    let forecasts = trainer.forecasts(true)?;
    for (i, fc) in forecasts.iter().take(3).enumerate() {
        let s = &trainer.set.series[i];
        println!("  {}: forecast {:?} … actual {:?}", s.id,
                 &fc[..3], &s.test[..3]);
    }

    // 5. Serve the trained model through the forecast service (the
    //    service thread builds its own backend via the same selector).
    let service = ForecastService::start(
        default_backend, Frequency::Quarterly, trainer.state.clone(),
        ServiceOptions::default())?;
    let demo = trainer.set.series[0].clone();
    let resp = service.handle.forecast(ForecastRequest {
        id: demo.id.clone(),
        values: demo.refit.clone(),
        category: fast_esrnn::config::Category::Other,
    })?;
    assert_eq!(resp.forecast.len(), 8);
    assert!(resp.forecast.iter().all(|v| v.is_finite() && *v > 0.0));
    println!("\nserved forecast for `{}`: {:?}", resp.id, &resp.forecast[..4]);
    let st = service.handle.stats()?;
    println!("service stats: {} requests, {} batches, {} padded slots",
             st.requests, st.batches, st.padded_slots);
    Ok(())
}
