//! Quickstart: train ES-RNN on a small synthetic quarterly corpus and
//! forecast — the 60-second tour of the public API.
//!
//! Run with: `cargo run --release --example quickstart`
//! (requires `make artifacts` first).

use fast_esrnn::config::{Frequency, TrainConfig};
use fast_esrnn::coordinator::{EvalSplit, Trainer};
use fast_esrnn::data::{generate, GenOptions};
use fast_esrnn::runtime::Engine;

fn main() -> anyhow::Result<()> {
    // 1. Open the AOT artifacts (HLO text compiled from JAX + Pallas).
    let engine = Engine::load("artifacts")?;
    println!("PJRT platform: {}", engine.platform());

    // 2. A small deterministic corpus (1/400 of the M4 Table 2 counts).
    let corpus = generate(&GenOptions { scale: 400, ..Default::default() });
    println!("corpus: {} series", corpus.len());

    // 3. Train quarterly ES-RNN for a few epochs.
    let tc = TrainConfig {
        epochs: 5,
        batch_size: 16,
        ..Default::default()
    };
    let mut trainer = Trainer::new(&engine, Frequency::Quarterly, &corpus, tc)?;
    println!("training on {} equalized series…", trainer.series_count());
    let report = trainer.train(true)?;

    // 4. Score the test holdout and print a few forecasts.
    let test = trainer.evaluate(EvalSplit::Test)?;
    println!("\ntest sMAPE {:.3}  MASE {:.3}  ({} series, {:.1}s train)",
             test.smape, test.mase, test.count, report.train_secs);

    let forecasts = trainer.forecasts(true)?;
    for (i, fc) in forecasts.iter().take(3).enumerate() {
        let s = &trainer.set.series[i];
        println!("  {}: forecast {:?} … actual {:?}", s.id,
                 &fc[..3], &s.test[..3]);
    }
    Ok(())
}
