//! Forecast server demo: train briefly, then serve concurrent forecast
//! requests through the dynamic-batching service (the vLLM-router-shaped
//! part of the coordinator), reporting latency and throughput.
//!
//! Run with: `cargo run --release --example forecast_server`

use std::time::Instant;

use fast_esrnn::config::{Frequency, TrainConfig};
use fast_esrnn::coordinator::Trainer;
use fast_esrnn::data::{generate, GenOptions};
use fast_esrnn::forecast::{ForecastRequest, ForecastService, ServiceOptions};
use fast_esrnn::runtime::{default_backend, Backend};

fn main() -> anyhow::Result<()> {
    let freq = Frequency::Quarterly;

    // Train a small model to serve (2 epochs is enough for a demo).
    let state = {
        let backend = default_backend()?;
        println!("backend: {}", backend.platform());
        let corpus = generate(&GenOptions { scale: 400, ..Default::default() })?;
        let tc = TrainConfig { epochs: 2, batch_size: 16, ..Default::default() };
        let mut trainer = Trainer::new(backend.as_ref(), freq, &corpus, tc)?;
        trainer.train(false)?;
        println!("trained {} on {} series", freq.name(),
                 trainer.series_count());
        trainer.state.clone()
    };

    // Start the service (it builds its own backend on a dedicated thread).
    let service = ForecastService::start(
        default_backend, freq, state,
        ServiceOptions { max_batch: 64, ..Default::default() })?;

    // Request generators: a fresh corpus the model never saw.
    let corpus = generate(&GenOptions { scale: 300, seed: 777,
                                        freqs: Some(vec![freq]) })?;
    let candidates: Vec<_> = corpus
        .series
        .iter()
        .filter(|s| s.len() >= 72)
        .collect();
    println!("{} candidate request series", candidates.len());

    // Throughput test: submit a burst, await all.
    let n_req = 200usize;
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for i in 0..n_req {
        let s = candidates[i % candidates.len()];
        rxs.push(service.handle.submit(ForecastRequest {
            id: format!("{}#{i}", s.id),
            values: s.values.clone(),
            category: s.category,
        })?);
    }
    let mut ok = 0;
    for rx in rxs {
        let r = rx.recv()??;
        assert_eq!(r.forecast.len(), 8);
        assert!(r.forecast.iter().all(|v| v.is_finite() && *v > 0.0));
        ok += 1;
    }
    let burst_secs = t0.elapsed().as_secs_f64();

    // Latency test: sequential single requests (batch of 1 path).
    let mut lat = Vec::new();
    for i in 0..30 {
        let s = candidates[i % candidates.len()];
        let t = Instant::now();
        service.handle.forecast(ForecastRequest {
            id: s.id.clone(),
            values: s.values.clone(),
            category: s.category,
        })?;
        lat.push(t.elapsed().as_secs_f64());
    }
    lat.sort_by(|a, b| a.total_cmp(b));

    let st = service.handle.stats()?;
    println!("\nburst: {ok}/{n_req} ok in {burst_secs:.3}s \
              ({:.1} req/s) over {} dynamic batches ({} padded slots)",
             ok as f64 / burst_secs, st.batches, st.padded_slots);
    println!("sequential latency: p50 {:.2}ms  p95 {:.2}ms",
             lat[lat.len() / 2] * 1e3, lat[lat.len() * 95 / 100] * 1e3);
    Ok(())
}
