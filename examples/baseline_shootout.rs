//! Baseline shootout: every classical method vs the holdout on all three
//! modeled frequencies — the statistical context for Table 4 (the M4
//! "Comb" benchmark row is the one the paper reports against).
//!
//! Run with: `cargo run --release --example baseline_shootout`

use fast_esrnn::baselines::all_baselines;
use fast_esrnn::config::{NetworkConfig, MODELED_FREQS};
use fast_esrnn::data::{generate, split_corpus, GenOptions};
use fast_esrnn::metrics::{mase, smape, MetricAccumulator};

fn main() -> anyhow::Result<()> {
    let corpus = generate(&GenOptions::default())?; // 1/100 Table 2 scale
    println!("corpus: {} series\n", corpus.len());

    // Per-frequency sMAPE for each method (Table 4's row structure).
    let mut table: Vec<(String, MetricAccumulator)> = all_baselines()
        .iter()
        .map(|m| (m.name().to_string(), MetricAccumulator::new()))
        .collect();

    for freq in MODELED_FREQS {
        let net = NetworkConfig::for_freq(freq)?;
        let set = split_corpus(&corpus, &net)?;
        println!("{}: {} series ({} discarded by §5.2)",
                 freq.name(), set.series.len(), set.discarded);
        for (mi, method) in all_baselines().iter().enumerate() {
            for sp in &set.series {
                let fc = method.forecast(&sp.refit, net.seasonality,
                                         net.horizon);
                table[mi].1.add(freq.name(), smape(&fc, &sp.test),
                                mase(&fc, &sp.test, sp.mase_scale));
            }
        }
    }

    println!("\n{:<14} {:>8} {:>10} {:>8} {:>9}", "method", "Yearly",
             "Quarterly", "Monthly", "Average");
    let freq_names = ["yearly", "quarterly", "monthly"];
    for (name, acc) in &table {
        let cells: Vec<f64> = freq_names
            .iter()
            .map(|f| acc.mean_smape(f).unwrap_or(f64::NAN))
            .collect();
        let avg = acc.weighted_smape(&freq_names).unwrap_or(f64::NAN);
        println!("{:<14} {:>8.3} {:>10.3} {:>8.3} {:>9.3}", name, cells[0],
                 cells[1], cells[2], avg);
    }
    println!("\n(Comb is the M4 competition benchmark the paper's Table 4 \
              reports against.)");
    Ok(())
}
