//! END-TO-END DRIVER: train ES-RNN on the full synthetic M4-like corpus
//! for all three modeled frequencies, log the loss curves, score the test
//! holdout against the Comb benchmark, and print the Table 4 / Table 6
//! analogues.
//!
//! This is the complete system doing the paper's experiment — ES layer +
//! dilated LSTM inside the train step (native Rust graph, or Pallas
//! kernels via the pjrt backend), Rust owning the per-series parameter
//! store, batching, epochs and evaluation.
//!
//! Run with: `cargo run --release --example m4_train` (≈ minutes), or set
//! FAST_ESRNN_SCALE / FAST_ESRNN_EPOCHS to shrink/grow the run.

use fast_esrnn::baselines::{Comb, Forecaster};
use fast_esrnn::config::{NetworkConfig, TrainConfig, ALL_CATEGORIES,
                         MODELED_FREQS};
use fast_esrnn::coordinator::{EvalSplit, Trainer};
use fast_esrnn::data::{generate, split_corpus, GenOptions};
use fast_esrnn::metrics::{mase, smape, MetricAccumulator};
use fast_esrnn::runtime::{default_backend, Backend};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let scale = env_usize("FAST_ESRNN_SCALE", 100);
    let epochs = env_usize("FAST_ESRNN_EPOCHS", 15);
    let batch = env_usize("FAST_ESRNN_BATCH", 64);

    let backend = default_backend()?;
    println!("backend: {} | corpus scale 1/{scale} | {epochs} epochs \
              | batch {batch}", backend.platform());
    let corpus = generate(&GenOptions { scale, ..Default::default() })?;
    println!("corpus: {} series", corpus.len());

    let mut esrnn_rows: Vec<(String, f64, f64, usize, f64)> = Vec::new();
    let mut comb_rows: Vec<(String, f64)> = Vec::new();
    let mut cat_table: Vec<(String, MetricAccumulator)> = Vec::new();

    for freq in MODELED_FREQS {
        let net = NetworkConfig::for_freq(freq)?;
        println!("\n=== {} ===", freq.name());
        let tc = TrainConfig {
            epochs,
            batch_size: batch,
            ..Default::default()
        };
        let mut trainer = Trainer::new(backend.as_ref(), freq, &corpus, tc)?;
        println!("{} series survive §5.2 (of {})", trainer.series_count(),
                 trainer.set.total);

        let report = trainer.train(true)?;
        println!("loss curve: {:?}",
                 report.epoch_losses.iter().map(|v| (v * 1e4).round() / 1e4)
                       .collect::<Vec<_>>());

        let test = trainer.evaluate(EvalSplit::Test)?;
        esrnn_rows.push((freq.name().into(), test.smape, test.mase,
                         test.count, report.train_secs));
        cat_table.push((freq.name().into(), test.per_category.clone()));

        // Comb benchmark on the same splits (Table 4's baseline row).
        let set = split_corpus(&corpus, &net)?;
        let mut s_acc = 0.0;
        for sp in &set.series {
            let fc = Comb.forecast(&sp.refit, net.seasonality, net.horizon);
            s_acc += smape(&fc, &sp.test);
            let _ = mase(&fc, &sp.test, sp.mase_scale);
        }
        comb_rows.push((freq.name().into(), s_acc / set.series.len() as f64));

        println!("{}", trainer.telemetry.report());
    }

    // ---- Table 4 analogue ----
    println!("\n== Table 4 analogue: sMAPE by frequency ==");
    println!("{:<20} {:>8} {:>10} {:>8} {:>9}", "model", "Yearly",
             "Quarterly", "Monthly", "Average");
    let avg = |rows: &[(String, f64)]| {
        rows.iter().map(|r| r.1).sum::<f64>() / rows.len() as f64
    };
    let comb_simple: Vec<(String, f64)> = comb_rows.clone();
    println!("{:<20} {:>8.3} {:>10.3} {:>8.3} {:>9.3}", "Comb (benchmark)",
             comb_rows[0].1, comb_rows[1].1, comb_rows[2].1,
             avg(&comb_simple));
    let es: Vec<(String, f64)> =
        esrnn_rows.iter().map(|r| (r.0.clone(), r.1)).collect();
    println!("{:<20} {:>8.3} {:>10.3} {:>8.3} {:>9.3}", "ES-RNN (ours)",
             esrnn_rows[0].1, esrnn_rows[1].1, esrnn_rows[2].1, avg(&es));
    let improvement = 100.0 * (avg(&comb_simple) - avg(&es)) / avg(&comb_simple);
    println!("{:<20} {:>37.1}%", "improvement vs Comb", improvement);

    // ---- Table 6 analogue ----
    println!("\n== Table 6 analogue: sMAPE by category ==");
    println!("{:<14} {:>8} {:>10} {:>8}", "category", "Yearly", "Quarterly",
             "Monthly");
    for cat in ALL_CATEGORIES {
        let cells: Vec<String> = cat_table
            .iter()
            .map(|(_, acc)| {
                acc.mean_smape(cat.name())
                   .map(|v| format!("{v:.2}"))
                   .unwrap_or_else(|| "-".into())
            })
            .collect();
        println!("{:<14} {:>8} {:>10} {:>8}", cat.name(), cells[0], cells[1],
                 cells[2]);
    }

    println!("\n== run summary ==");
    for (f, s, m, n, secs) in &esrnn_rows {
        println!("{f:<10} sMAPE {s:.3}  MASE {m:.3}  ({n} series, {secs:.1}s)");
    }
    Ok(())
}
