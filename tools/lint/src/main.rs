//! fesrnn-lint — token-level repo linter for the fast-esrnn workspace.
//!
//! The linter walks `rust/src` (plus `rust/tests`, `benches`, `examples`
//! for the file-agnostic rules) with its own lexer — strings, raw
//! strings, char literals and comments are handled, no `syn` — and
//! enforces the repo invariants as machine-checked rules:
//!
//! * **R1** — no `.unwrap()` / `.expect(` / `panic!` in the serving
//!   request path (`forecast/{http,pool,shard,router,remote}.rs`)
//!   outside
//!   `#[cfg(test)]`. Unwraps whose receiver is a lock-family call
//!   (`lock()`, `read()`, `write()`, `wait(..)`, `join()`, …) are
//!   exempt: propagating lock poisoning by crashing is deliberate
//!   policy (a poisoned lock means a worker already panicked mid-update
//!   and the shared state can no longer be trusted).
//! * **R2** — no `thread::spawn` / `thread::scope` / `thread::Builder`
//!   outside `runtime/native/pool.rs` and
//!   `forecast/{pool,http,remote}.rs`: every production thread belongs
//!   to one of the pools (remote.rs owns the health prober and the
//!   short-lived hedged-read replica threads).
//! * **R3** — no allocation-prone calls (`Vec::new`, `vec!`, `to_vec`,
//!   `clone`, `format!`, `Box::new`, `collect`) inside regions fenced
//!   by `// lint:hot-path-begin` / `// lint:hot-path-end` — the static
//!   twin of the `CountingAlloc` runtime gate over the PR-6
//!   `train_step_inplace` steady-state kernels.
//! * **R4** — every `unsafe` block / `unsafe impl` carries a
//!   `// SAFETY:` comment directly above (or trailing on) its line.
//! * **R5** — a per-function lock-acquisition extractor builds a
//!   cross-file lock-order graph over the mutexes/rwlocks annotated
//!   with `// lint:lock-name(<name>)` and fails on cycles (static
//!   deadlock detection). Guard liveness follows `let`-bound guards to
//!   `drop(g)` / end of scope; statement temporaries die at `;`.
//!   Limited interprocedural propagation: a method call resolving to a
//!   uniquely-named function in the scanned set contributes that
//!   function's transitive acquisition set as edges from every lock
//!   held at the call site.
//! * **R6** — every file in `rust/tests/` must be registered as a
//!   `[[test]]` target in `Cargo.toml` *and* named in
//!   `.github/workflows/ci.yml`, so suites cannot silently drop out of
//!   CI.
//! * **R7** — no NaN-unsafe `.partial_cmp(..).unwrap()` comparators
//!   anywhere (use `total_cmp`); R1's sibling rule.
//!
//! Violations are suppressible only via
//! `// lint:allow(<rule>) — <reason>` on (or directly above) the
//! offending line; an allow without a reason is itself a violation.
//! The linter self-tests against embedded fixture snippets that trip
//! every rule (`cargo test -p fesrnn-lint`).

use std::collections::{BTreeMap, BTreeSet, HashMap, HashSet};
use std::fmt::Write as _;
use std::fs;
use std::path::{Path, PathBuf};

// ------------------------------------------------------------------ model

#[derive(Debug, Clone)]
struct Tok {
    text: String,
    line: usize,
}

#[derive(Debug, Default)]
struct Scan {
    path: String,
    toks: Vec<Tok>,
    /// line -> rules suppressed on that line via lint:allow.
    allow: HashMap<usize, Vec<String>>,
    /// lint:allow comments missing the mandatory reason text.
    bad_allows: Vec<usize>,
    comment_lines: HashSet<usize>,
    safety_lines: HashSet<usize>,
    hot_begin: Vec<usize>,
    hot_end: Vec<usize>,
    /// (annotation line, lock name) from lint:lock-name comments.
    lock_names: Vec<(usize, String)>,
    /// Line ranges covered by `#[cfg(test)]` items / `#[test]` fns.
    test_ranges: Vec<(usize, usize)>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
struct Violation {
    rule: &'static str,
    path: String,
    line: usize,
    msg: String,
}

impl Violation {
    fn render(&self) -> String {
        format!("{} {}:{} {}", self.rule, self.path, self.line, self.msg)
    }
}

// ------------------------------------------------------------------ lexer

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Tokenize one source file; comments feed the directive side tables.
fn lex(path: &str, src: &str) -> Scan {
    let mut s = Scan { path: path.to_string(), ..Scan::default() };
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut i = 0usize;
    let mut line = 1usize;
    let mut code_on_line = false;
    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            code_on_line = false;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            let start = i;
            while i < n && b[i] != '\n' {
                i += 1;
            }
            let text: String = b[start..i].iter().collect();
            note_line_comment(&mut s, &text, line, code_on_line);
            continue;
        }
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let start_line = line;
            i += 2;
            let mut depth = 1usize;
            let mut text = String::new();
            while i < n && depth > 0 {
                if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                    continue;
                }
                if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                    continue;
                }
                if b[i] == '\n' {
                    line += 1;
                }
                text.push(b[i]);
                i += 1;
            }
            for l in start_line..=line {
                s.comment_lines.insert(l);
            }
            if text.contains("SAFETY:") {
                s.safety_lines.insert(start_line);
            }
            continue;
        }
        // Raw (and raw-byte) string literals: r"..", r#".."#, br#".."#.
        if c == 'r' || c == 'b' {
            if let Some((next_i, newlines)) = raw_string_span(&b, i) {
                let start_line = line;
                i = next_i;
                line += newlines;
                s.toks.push(Tok { text: "\u{1}str".into(), line: start_line });
                code_on_line = true;
                continue;
            }
        }
        if c == '"' {
            let start_line = line;
            i += 1;
            while i < n {
                if b[i] == '\\' {
                    i += 2;
                    continue;
                }
                if b[i] == '"' {
                    i += 1;
                    break;
                }
                if b[i] == '\n' {
                    line += 1;
                }
                i += 1;
            }
            s.toks.push(Tok { text: "\u{1}str".into(), line: start_line });
            code_on_line = true;
            continue;
        }
        if c == '\'' {
            // Lifetime (`'a`) vs char literal (`'x'`, `'\n'`).
            let c1 = if i + 1 < n { b[i + 1] } else { '\0' };
            let c2 = if i + 2 < n { b[i + 2] } else { '\0' };
            if is_ident_start(c1) && c2 != '\'' {
                i += 1;
                while i < n && is_ident_char(b[i]) {
                    i += 1;
                }
                s.toks.push(Tok { text: "\u{1}life".into(), line });
            } else {
                i += 1;
                while i < n {
                    if b[i] == '\\' {
                        i += 2;
                        continue;
                    }
                    if b[i] == '\'' {
                        i += 1;
                        break;
                    }
                    i += 1;
                }
                s.toks.push(Tok { text: "\u{1}char".into(), line });
            }
            code_on_line = true;
            continue;
        }
        if is_ident_start(c) || c.is_ascii_digit() {
            let start = i;
            while i < n && is_ident_char(b[i]) {
                i += 1;
            }
            let text: String = b[start..i].iter().collect();
            s.toks.push(Tok { text, line });
            code_on_line = true;
            continue;
        }
        s.toks.push(Tok { text: c.to_string(), line });
        code_on_line = true;
        i += 1;
    }
    s.test_ranges = find_test_ranges(&s.toks);
    s
}

/// `r"…"`, `r#"…"#`, `br#"…"#` — returns (index past literal, newlines).
fn raw_string_span(b: &[char], at: usize) -> Option<(usize, usize)> {
    let mut j = at;
    if b[j] == 'b' {
        j += 1;
        if j >= b.len() || b[j] != 'r' {
            return None;
        }
    }
    if b[j] != 'r' {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while j < b.len() && b[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if j >= b.len() || b[j] != '"' {
        return None;
    }
    j += 1;
    let mut newlines = 0usize;
    while j < b.len() {
        if b[j] == '\n' {
            newlines += 1;
        }
        if b[j] == '"' {
            let mut k = 0usize;
            while k < hashes && j + 1 + k < b.len() && b[j + 1 + k] == '#' {
                k += 1;
            }
            if k == hashes {
                return Some((j + 1 + hashes, newlines));
            }
        }
        j += 1;
    }
    Some((b.len(), newlines))
}

fn note_line_comment(s: &mut Scan, text: &str, line: usize, trailing: bool) {
    s.comment_lines.insert(line);
    if text.contains("SAFETY:") {
        s.safety_lines.insert(line);
    }
    // A trailing comment suppresses its own line; a standalone comment
    // suppresses the line below it.
    let target = if trailing { line } else { line + 1 };
    if let Some(pos) = text.find("lint:allow(") {
        let rest = &text[pos + "lint:allow(".len()..];
        if let Some(close) = rest.find(')') {
            let rules: Vec<String> = rest[..close]
                .split(',')
                .map(|r| r.trim().to_string())
                .filter(|r| !r.is_empty())
                .collect();
            let reason = rest[close + 1..]
                .trim_start_matches([' ', '\t', '—', '–', '-', ':']);
            if reason.trim().is_empty() || rules.is_empty() {
                s.bad_allows.push(line);
            } else {
                s.allow.entry(target).or_default().extend(rules);
            }
        } else {
            s.bad_allows.push(line);
        }
    }
    if text.contains("lint:hot-path-begin") {
        s.hot_begin.push(line);
    }
    if text.contains("lint:hot-path-end") {
        s.hot_end.push(line);
    }
    if let Some(pos) = text.find("lint:lock-name(") {
        let rest = &text[pos + "lint:lock-name(".len()..];
        if let Some(close) = rest.find(')') {
            s.lock_names.push((line, rest[..close].trim().to_string()));
        }
    }
}

fn tok<'a>(toks: &'a [Tok], i: usize) -> &'a str {
    toks.get(i).map_or("", |t| t.text.as_str())
}

/// Index of the `}` matching the `{` at `open`.
fn match_brace(toks: &[Tok], open: usize) -> usize {
    let mut depth = 0i64;
    let mut i = open;
    while i < toks.len() {
        match tok(toks, i) {
            "{" => depth += 1,
            "}" => {
                depth -= 1;
                if depth == 0 {
                    return i;
                }
            }
            _ => {}
        }
        i += 1;
    }
    toks.len().saturating_sub(1)
}

/// Line ranges under `#[cfg(test)]` items and `#[test]` functions.
fn find_test_ranges(toks: &[Tok]) -> Vec<(usize, usize)> {
    let mut ranges = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        let is_cfg_test = tok(toks, i) == "#"
            && tok(toks, i + 1) == "["
            && tok(toks, i + 2) == "cfg"
            && tok(toks, i + 3) == "("
            && tok(toks, i + 4) == "test"
            && tok(toks, i + 5) == ")"
            && tok(toks, i + 6) == "]";
        let is_test_attr = tok(toks, i) == "#"
            && tok(toks, i + 1) == "["
            && tok(toks, i + 2) == "test"
            && tok(toks, i + 3) == "]";
        if is_cfg_test || is_test_attr {
            let mut j = i + if is_cfg_test { 7 } else { 4 };
            while j < toks.len() && tok(toks, j) != "{" {
                j += 1;
            }
            if j < toks.len() {
                let close = match_brace(toks, j);
                ranges.push((toks[i].line, toks[close].line));
                i = close + 1;
                continue;
            }
        }
        i += 1;
    }
    ranges
}

fn in_ranges(ranges: &[(usize, usize)], line: usize) -> bool {
    ranges.iter().any(|&(lo, hi)| line >= lo && line <= hi)
}

fn allowed(scan: &Scan, rule: &str, line: usize) -> bool {
    scan.allow
        .get(&line)
        .is_some_and(|rs| rs.iter().any(|r| r == rule))
}

fn push(out: &mut Vec<Violation>, scan: &Scan, rule: &'static str,
        line: usize, msg: String) {
    if !allowed(scan, rule, line) {
        out.push(Violation { rule, path: scan.path.clone(), line, msg });
    }
}

// ------------------------------------------------------------- rules R1/R7

const SERVING_FILES: [&str; 7] = [
    "forecast/http.rs",
    "forecast/pool.rs",
    "forecast/shard.rs",
    "forecast/router.rs",
    "forecast/remote.rs",
    "forecast/state.rs",
    "forecast/api.rs",
];

const LOCK_FAMILY: [&str; 9] = [
    "lock", "read", "write", "wait", "wait_timeout", "wait_while", "join",
    "get_mut", "into_inner",
];

fn is_serving_file(path: &str) -> bool {
    SERVING_FILES.iter().any(|f| path.ends_with(f))
}

/// `.unwrap()` / `.expect(` whose receiver is a lock-family call — the
/// deliberate crash-on-poison pattern R1 exempts.
fn is_poison_unwrap(toks: &[Tok], dot: usize) -> bool {
    if dot == 0 || tok(toks, dot - 1) != ")" {
        return false;
    }
    let mut depth = 0i64;
    let mut j = dot - 1;
    loop {
        match tok(toks, j) {
            ")" => depth += 1,
            "(" => depth -= 1,
            _ => {}
        }
        if depth == 0 {
            break;
        }
        if j == 0 {
            return false;
        }
        j -= 1;
    }
    j > 0 && LOCK_FAMILY.contains(&tok(toks, j - 1))
}

fn rule_r1(scan: &Scan, out: &mut Vec<Violation>) {
    if !is_serving_file(&scan.path) {
        return;
    }
    let toks = &scan.toks;
    for i in 0..toks.len() {
        let line = toks[i].line;
        if in_ranges(&scan.test_ranges, line) {
            continue;
        }
        if tok(toks, i) == "."
            && tok(toks, i + 1) == "unwrap"
            && tok(toks, i + 2) == "("
            && tok(toks, i + 3) == ")"
            && !is_poison_unwrap(toks, i)
        {
            push(out, scan, "R1", line,
                 "`.unwrap()` in the serving request path (use typed \
                  errors; only lock-poison unwraps are exempt)"
                     .into());
        }
        if tok(toks, i) == "."
            && tok(toks, i + 1) == "expect"
            && tok(toks, i + 2) == "("
            && !is_poison_unwrap(toks, i)
        {
            push(out, scan, "R1", line,
                 "`.expect(..)` in the serving request path".into());
        }
        if tok(toks, i) == "panic" && tok(toks, i + 1) == "!" {
            push(out, scan, "R1", line,
                 "`panic!` in the serving request path".into());
        }
    }
}

fn rule_r7(scan: &Scan, out: &mut Vec<Violation>) {
    let toks = &scan.toks;
    for i in 0..toks.len() {
        if tok(toks, i) == "."
            && tok(toks, i + 1) == "partial_cmp"
            && tok(toks, i + 2) == "("
        {
            let mut depth = 0i64;
            let mut j = i + 2;
            while j < toks.len() {
                match tok(toks, j) {
                    "(" => depth += 1,
                    ")" => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                j += 1;
            }
            if tok(toks, j + 1) == "." && tok(toks, j + 2) == "unwrap" {
                push(out, scan, "R7", toks[i].line,
                     "NaN-unsafe `partial_cmp(..).unwrap()` comparator \
                      (use `total_cmp`)"
                         .into());
            }
        }
    }
}

// ---------------------------------------------------------------- rule R2

// `forecast/remote.rs` spawns the per-remote health prober and the
// hedged-read replica threads, and `forecast/shard.rs` spawns the
// async observe replica fan-out — all deliberate, all joined/detached
// by design.
const SPAWN_FILES: [&str; 5] = [
    "runtime/native/pool.rs",
    "forecast/pool.rs",
    "forecast/http.rs",
    "forecast/remote.rs",
    "forecast/shard.rs",
];

fn rule_r2(scan: &Scan, out: &mut Vec<Violation>) {
    if SPAWN_FILES.iter().any(|f| scan.path.ends_with(f)) {
        return;
    }
    let toks = &scan.toks;
    for i in 0..toks.len() {
        let line = toks[i].line;
        if in_ranges(&scan.test_ranges, line) {
            continue;
        }
        if tok(toks, i) == "thread"
            && tok(toks, i + 1) == ":"
            && tok(toks, i + 2) == ":"
            && matches!(tok(toks, i + 3), "spawn" | "scope" | "Builder")
        {
            push(out, scan, "R2", line,
                 format!("`thread::{}` outside the compute/serving pools",
                         tok(toks, i + 3)));
        }
    }
}

// ---------------------------------------------------------------- rule R3

fn hot_ranges(scan: &Scan, out: &mut Vec<Violation>) -> Vec<(usize, usize)> {
    if scan.hot_begin.len() != scan.hot_end.len() {
        out.push(Violation {
            rule: "R3",
            path: scan.path.clone(),
            line: *scan
                .hot_begin
                .last()
                .or(scan.hot_end.last())
                .unwrap_or(&0),
            msg: "unbalanced lint:hot-path-begin/end fences".into(),
        });
        return Vec::new();
    }
    scan.hot_begin
        .iter()
        .zip(&scan.hot_end)
        .map(|(&b, &e)| (b, e))
        .collect()
}

fn rule_r3(scan: &Scan, out: &mut Vec<Violation>) {
    let ranges = hot_ranges(scan, out);
    if ranges.is_empty() {
        return;
    }
    let toks = &scan.toks;
    for i in 0..toks.len() {
        let line = toks[i].line;
        if !in_ranges(&ranges, line) {
            continue;
        }
        let hit: Option<&str> = if tok(toks, i) == "Vec"
            && tok(toks, i + 1) == ":"
            && tok(toks, i + 2) == ":"
            && tok(toks, i + 3) == "new"
        {
            Some("Vec::new")
        } else if tok(toks, i) == "Box"
            && tok(toks, i + 1) == ":"
            && tok(toks, i + 2) == ":"
            && tok(toks, i + 3) == "new"
        {
            Some("Box::new")
        } else if tok(toks, i) == "vec" && tok(toks, i + 1) == "!" {
            Some("vec!")
        } else if tok(toks, i) == "format" && tok(toks, i + 1) == "!" {
            Some("format!")
        } else if matches!(tok(toks, i + 1), "clone" | "to_vec" | "collect"
                           | "to_string" | "to_owned")
            && (tok(toks, i) == "." || tok(toks, i) == ":")
            && (tok(toks, i + 2) == "(" || tok(toks, i + 2) == ":")
        {
            Some(match tok(toks, i + 1) {
                "clone" => "clone",
                "to_vec" => "to_vec",
                "collect" => "collect",
                "to_string" => "to_string",
                _ => "to_owned",
            })
        } else {
            None
        };
        if let Some(name) = hit {
            push(out, scan, "R3", line,
                 format!("allocation-prone `{name}` inside a \
                          lint:hot-path fence"));
        }
    }
}

// ---------------------------------------------------------------- rule R4

fn rule_r4(scan: &Scan, out: &mut Vec<Violation>) {
    let toks = &scan.toks;
    for i in 0..toks.len() {
        if tok(toks, i) != "unsafe" {
            continue;
        }
        let next = tok(toks, i + 1);
        if next != "{" && next != "impl" {
            continue; // `unsafe fn` declarations are R4-exempt (clippy
                      // semantics: the body, not the signature, needs
                      // justification at the call site).
        }
        let line = toks[i].line;
        let mut ok = scan.safety_lines.contains(&line);
        let mut l = line.saturating_sub(1);
        while !ok && l > 0 && scan.comment_lines.contains(&l) {
            ok = scan.safety_lines.contains(&l);
            l -= 1;
        }
        if !ok {
            push(out, scan, "R4", line,
                 format!("`unsafe {}` without a `// SAFETY:` comment",
                         if next == "{" { "block" } else { "impl" }));
        }
    }
}

// ---------------------------------------------------------------- rule R5

#[derive(Debug, Clone)]
struct GuardSlot {
    /// Binding name; `None` for statement temporaries.
    name: Option<String>,
    lock: String,
    depth: i64,
}

#[derive(Debug, Default)]
struct FnInfo {
    /// Locks this function acquires directly.
    direct: BTreeSet<String>,
    /// Method/function names it calls with the locks held at that call.
    calls: Vec<(Vec<String>, String)>,
    /// Direct (held -> acquired) edges with the acquisition line.
    edges: Vec<(String, String, usize)>,
}

/// Registered locks: field ident -> [(file, qualified name)].
fn build_registry(scans: &[Scan], out: &mut Vec<Violation>)
                  -> HashMap<String, Vec<(String, String)>> {
    let mut reg: HashMap<String, Vec<(String, String)>> = HashMap::new();
    for scan in scans {
        for (line, qual) in &scan.lock_names {
            // The annotation binds to the field ident on its own line
            // (trailing comment) or the next line.
            let mut field = None;
            for i in 0..scan.toks.len() {
                let l = scan.toks[i].line;
                if (l == *line || l == line + 1)
                    && tok(&scan.toks, i + 1) == ":"
                    && tok(&scan.toks, i + 2) != ":"
                    && is_ident_start(
                        scan.toks[i].text.chars().next().unwrap_or(' '))
                {
                    field = Some(scan.toks[i].text.clone());
                    break;
                }
            }
            match field {
                Some(f) => reg
                    .entry(f)
                    .or_default()
                    .push((scan.path.clone(), qual.clone())),
                None => out.push(Violation {
                    rule: "R5",
                    path: scan.path.clone(),
                    line: *line,
                    msg: format!("lint:lock-name({qual}) is not attached \
                                  to a field declaration"),
                }),
            }
        }
    }
    reg
}

/// Resolve the receiver of a `.lock()/.read()/.write()` chain ending at
/// the `.` token `dot` to a registered lock (file-local first).
fn resolve_receiver(toks: &[Tok], dot: usize, file: &str,
                    reg: &HashMap<String, Vec<(String, String)>>)
                    -> Option<String> {
    let mut j = dot;
    if j == 0 {
        return None;
    }
    j -= 1;
    if tok(toks, j) == "]" {
        let mut depth = 0i64;
        loop {
            match tok(toks, j) {
                "]" => depth += 1,
                "[" => depth -= 1,
                _ => {}
            }
            if depth == 0 {
                break;
            }
            if j == 0 {
                return None;
            }
            j -= 1;
        }
        if j == 0 {
            return None;
        }
        j -= 1;
    }
    let field = tok(toks, j);
    let entries = reg.get(field)?;
    if let Some((_, q)) = entries.iter().find(|(f, _)| f == file) {
        return Some(q.clone());
    }
    if entries.len() == 1 {
        return Some(entries[0].1.clone());
    }
    None
}

/// Is `toks[at..]` the start of a statement binding (`let [mut] x = …`)?
/// Walks backwards from the receiver chain start.
fn binding_name(toks: &[Tok], chain_start: usize) -> Option<String> {
    let mut j = chain_start;
    if j == 0 || tok(toks, j - 1) != "=" {
        return None;
    }
    j -= 1; // at '='
    if j == 0 {
        return None;
    }
    let name = tok(toks, j - 1).to_string();
    if !name.chars().next().map(is_ident_start).unwrap_or(false) {
        return None;
    }
    let mut k = j - 1;
    if k > 0 && tok(toks, k - 1) == "mut" {
        k -= 1;
    }
    if k > 0 && tok(toks, k - 1) == "let" {
        return Some(name);
    }
    None
}

/// Start of the receiver chain for the method call whose `.` is at `dot`
/// (walks back over `ident`, `.`, `self`, and balanced `[..]`).
fn chain_start(toks: &[Tok], dot: usize) -> usize {
    let mut j = dot;
    loop {
        if j == 0 {
            return 0;
        }
        let prev = tok(toks, j - 1);
        if prev == "]" {
            let mut depth = 0i64;
            let mut k = j - 1;
            loop {
                match tok(toks, k) {
                    "]" => depth += 1,
                    "[" => depth -= 1,
                    _ => {}
                }
                if depth == 0 {
                    break;
                }
                if k == 0 {
                    return 0;
                }
                k -= 1;
            }
            j = k;
            continue;
        }
        if prev == "."
            || prev
                .chars()
                .next()
                .map(|c| is_ident_start(c) || c.is_ascii_digit())
                .unwrap_or(false)
        {
            j -= 1;
            continue;
        }
        return j;
    }
}

/// Extract per-function acquisition info for one file.
fn extract_fns(scan: &Scan,
               reg: &HashMap<String, Vec<(String, String)>>)
               -> BTreeMap<String, FnInfo> {
    let toks = &scan.toks;
    let mut fns: BTreeMap<String, FnInfo> = BTreeMap::new();
    let mut i = 0usize;
    while i < toks.len() {
        if tok(toks, i) != "fn" {
            i += 1;
            continue;
        }
        let name = tok(toks, i + 1).to_string();
        // Find the body `{`, skipping the parameter list and any
        // parenthesized groups in the return type.
        let mut j = i + 2;
        let mut paren = 0i64;
        while j < toks.len() {
            match tok(toks, j) {
                "(" => paren += 1,
                ")" => paren -= 1,
                "{" if paren == 0 => break,
                ";" if paren == 0 => break, // trait method, no body
                "}" if paren == 0 => break, // `fn(..)` pointer type, not a def
                _ => {}
            }
            j += 1;
        }
        if j >= toks.len() || tok(toks, j) != "{" {
            i = j;
            continue;
        }
        let close = match_brace(toks, j);
        let info = scan_body(scan, j, close, reg);
        fns.entry(name).or_default().merge(info);
        i = close + 1;
    }
    fns
}

impl FnInfo {
    fn merge(&mut self, other: FnInfo) {
        self.direct.extend(other.direct);
        self.calls.extend(other.calls);
        self.edges.extend(other.edges);
    }
}

fn scan_body(scan: &Scan, open: usize, close: usize,
             reg: &HashMap<String, Vec<(String, String)>>) -> FnInfo {
    let toks = &scan.toks;
    let mut info = FnInfo::default();
    let mut depth = 0i64;
    let mut live: Vec<GuardSlot> = Vec::new();
    let mut i = open;
    while i <= close {
        match tok(toks, i) {
            "{" => {
                depth += 1;
                // A block opener ends the current statement: temporaries
                // created in the statement head are (approximately)
                // dead once the body runs.
                live.retain(|g| g.name.is_some() || g.depth != depth - 1);
            }
            "}" => {
                depth -= 1;
                live.retain(|g| g.depth <= depth);
            }
            ";" => {
                live.retain(|g| g.name.is_some() || g.depth != depth);
            }
            "drop" if tok(toks, i + 1) == "("
                && tok(toks, i + 3) == ")" =>
            {
                let victim = tok(toks, i + 2).to_string();
                live.retain(|g| g.name.as_deref() != Some(victim.as_str()));
            }
            "." => {
                let m = tok(toks, i + 1);
                if tok(toks, i + 2) == "(" {
                    if matches!(m, "lock" | "read" | "write") {
                        if let Some(lockname) =
                            resolve_receiver(toks, i, &scan.path, reg)
                        {
                            let line = toks[i].line;
                            for g in &live {
                                if g.lock != lockname {
                                    info.edges.push((g.lock.clone(),
                                                     lockname.clone(),
                                                     line));
                                }
                            }
                            info.direct.insert(lockname.clone());
                            let start = chain_start(toks, i);
                            live.push(GuardSlot {
                                name: binding_name(toks, start),
                                lock: lockname,
                                depth,
                            });
                        }
                    } else if !matches!(m, "unwrap" | "expect" | "wait"
                                        | "wait_timeout" | "wait_while"
                                        | "notify_all" | "notify_one")
                        && !live.is_empty()
                    {
                        let held: Vec<String> =
                            live.iter().map(|g| g.lock.clone()).collect();
                        info.calls.push((held, m.to_string()));
                    }
                }
            }
            _ => {}
        }
        i += 1;
    }
    info
}

/// Build the cross-file lock graph and fail on cycles.
fn rule_r5(scans: &[Scan], out: &mut Vec<Violation>)
           -> BTreeMap<String, BTreeSet<String>> {
    let reg = build_registry(scans, out);
    let mut all_fns: BTreeMap<String, Vec<FnInfo>> = BTreeMap::new();
    for scan in scans {
        if scan.lock_names.is_empty() {
            continue;
        }
        for (name, info) in extract_fns(scan, &reg) {
            all_fns.entry(name).or_default().push(info);
        }
    }
    // Transitive acquisition sets, propagated only through call targets
    // whose name is defined exactly once in the scanned set (ambiguous
    // names are skipped — conservative, documented).
    let mut totals: BTreeMap<String, BTreeSet<String>> = all_fns
        .iter()
        .map(|(n, infos)| {
            let mut s = BTreeSet::new();
            for i in infos {
                s.extend(i.direct.iter().cloned());
            }
            (n.clone(), s)
        })
        .collect();
    loop {
        let mut changed = false;
        for (name, infos) in &all_fns {
            let mut add = BTreeSet::new();
            for info in infos {
                for (_, callee) in &info.calls {
                    if all_fns.get(callee).map(Vec::len) == Some(1) {
                        if let Some(t) = totals.get(callee) {
                            add.extend(t.iter().cloned());
                        }
                    }
                }
            }
            let t = totals.entry(name.clone()).or_default();
            for l in add {
                changed |= t.insert(l);
            }
        }
        if !changed {
            break;
        }
    }
    // Edges: direct + (held at call site -> callee's transitive set).
    let mut graph: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
    for infos in all_fns.values() {
        for info in infos {
            for (from, to, _line) in &info.edges {
                graph.entry(from.clone()).or_default().insert(to.clone());
            }
            for (held, callee) in &info.calls {
                if all_fns.get(callee).map(Vec::len) != Some(1) {
                    continue;
                }
                if let Some(t) = totals.get(callee) {
                    for h in held {
                        for l in t {
                            if l != h {
                                graph
                                    .entry(h.clone())
                                    .or_default()
                                    .insert(l.clone());
                            }
                        }
                    }
                }
            }
        }
    }
    // Cycle detection: color DFS (0 unvisited, 1 on stack, 2 done).
    // Graphs here are a dozen nodes, so recursion depth is a non-issue.
    fn dfs(n: &str, graph: &BTreeMap<String, BTreeSet<String>>,
           color: &mut HashMap<String, u8>) -> Option<(String, String)> {
        color.insert(n.to_string(), 1);
        if let Some(succs) = graph.get(n) {
            for s in succs {
                match color.get(s.as_str()).copied().unwrap_or(0) {
                    1 => return Some((n.to_string(), s.clone())),
                    0 => {
                        if let Some(cyc) = dfs(s, graph, color) {
                            return Some(cyc);
                        }
                    }
                    _ => {}
                }
            }
        }
        color.insert(n.to_string(), 2);
        None
    }
    let mut color: HashMap<String, u8> = HashMap::new();
    let roots: Vec<String> = graph.keys().cloned().collect();
    for r in roots {
        if color.get(r.as_str()).copied().unwrap_or(0) == 0 {
            if let Some((a, b)) = dfs(&r, &graph, &mut color) {
                out.push(Violation {
                    rule: "R5",
                    path: "(lock graph)".into(),
                    line: 0,
                    msg: format!("lock-order cycle: acquiring `{b}` while \
                                  holding `{a}` closes a loop"),
                });
                break;
            }
        }
    }
    graph
}

// ---------------------------------------------------------------- rule R6

fn word_in(text: &str, word: &str) -> bool {
    let mut from = 0usize;
    while let Some(pos) = text[from..].find(word) {
        let at = from + pos;
        let before_ok = at == 0
            || !text[..at]
                .chars()
                .next_back()
                .map(is_ident_char)
                .unwrap_or(false);
        let after = at + word.len();
        let after_ok = after >= text.len()
            || !text[after..]
                .chars()
                .next()
                .map(is_ident_char)
                .unwrap_or(false);
        if before_ok && after_ok {
            return true;
        }
        from = at + word.len();
    }
    false
}

fn rule_r6_strings(stems: &[String], cargo_toml: &str, ci_yml: &str)
                   -> Vec<Violation> {
    let mut out = Vec::new();
    for stem in stems {
        if !cargo_toml.contains(&format!("name = \"{stem}\"")) {
            out.push(Violation {
                rule: "R6",
                path: format!("rust/tests/{stem}.rs"),
                line: 0,
                msg: format!("test file has no `[[test]] name = \
                              \"{stem}\"` entry in Cargo.toml"),
            });
        }
        if !word_in(ci_yml, stem) {
            out.push(Violation {
                rule: "R6",
                path: format!("rust/tests/{stem}.rs"),
                line: 0,
                msg: format!("suite `{stem}` is never named in \
                              .github/workflows/ci.yml"),
            });
        }
    }
    out
}

// ----------------------------------------------------------------- driver

fn walk(dir: &Path, files: &mut Vec<PathBuf>) {
    let Ok(rd) = fs::read_dir(dir) else { return };
    let mut entries: Vec<PathBuf> =
        rd.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk(&p, files);
        } else if p.extension().is_some_and(|e| e == "rs") {
            files.push(p);
        }
    }
}

fn rel(root: &Path, p: &Path) -> String {
    p.strip_prefix(root)
        .unwrap_or(p)
        .to_string_lossy()
        .replace('\\', "/")
}

fn lint_tree(root: &Path) -> (Vec<Violation>,
                              BTreeMap<String, BTreeSet<String>>, usize) {
    let mut out = Vec::new();
    let mut src_scans = Vec::new();
    let mut other_scans = Vec::new();
    for (dir, is_src) in [("rust/src", true), ("rust/tests", false),
                          ("benches", false), ("examples", false)] {
        let mut files = Vec::new();
        walk(&root.join(dir), &mut files);
        for f in files {
            let Ok(src) = fs::read_to_string(&f) else { continue };
            let scan = lex(&rel(root, &f), &src);
            if is_src {
                src_scans.push(scan);
            } else {
                other_scans.push(scan);
            }
        }
    }
    for scan in &src_scans {
        rule_r1(scan, &mut out);
        rule_r2(scan, &mut out);
        rule_r3(scan, &mut out);
    }
    let graph = rule_r5(&src_scans, &mut out);
    for scan in src_scans.iter().chain(&other_scans) {
        rule_r4(scan, &mut out);
        rule_r7(scan, &mut out);
        for &line in &scan.bad_allows {
            out.push(Violation {
                rule: "ALLOW",
                path: scan.path.clone(),
                line,
                msg: "lint:allow without a rule list or reason \
                      (`// lint:allow(<rule>) — <reason>`)"
                    .into(),
            });
        }
    }
    // R6 against the real manifest + workflow.
    let mut stems = Vec::new();
    let mut tests = Vec::new();
    walk(&root.join("rust/tests"), &mut tests);
    for t in tests {
        if let Some(stem) = t.file_stem() {
            stems.push(stem.to_string_lossy().to_string());
        }
    }
    let cargo = fs::read_to_string(root.join("Cargo.toml"))
        .unwrap_or_default();
    let ci = fs::read_to_string(root.join(".github/workflows/ci.yml"))
        .unwrap_or_default();
    out.extend(rule_r6_strings(&stems, &cargo, &ci));
    let n_files = src_scans.len() + other_scans.len();
    out.sort_by(|a, b| {
        (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule))
    });
    (out, graph, n_files)
}

fn main() {
    let mut root = PathBuf::from(".");
    let mut report: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => {
                if let Some(v) = args.next() {
                    root = PathBuf::from(v);
                }
            }
            "--report" => report = args.next().map(PathBuf::from),
            other => {
                eprintln!("fesrnn-lint: unknown argument `{other}`");
                eprintln!("usage: fesrnn-lint [--root DIR] [--report FILE]");
                std::process::exit(2);
            }
        }
    }
    let (violations, graph, n_files) = lint_tree(&root);
    let mut text = String::new();
    for v in &violations {
        let _ = writeln!(text, "{}", v.render());
    }
    let _ = writeln!(text, "lock-order graph ({} edges):",
                     graph.values().map(BTreeSet::len).sum::<usize>());
    for (from, tos) in &graph {
        for to in tos {
            let _ = writeln!(text, "  {from} -> {to}");
        }
    }
    let _ = writeln!(text, "{} violation(s) across {} files",
                     violations.len(), n_files);
    print!("{text}");
    if let Some(path) = report {
        if let Err(e) = fs::write(&path, &text) {
            eprintln!("fesrnn-lint: cannot write {}: {e}", path.display());
            std::process::exit(2);
        }
    }
    if !violations.is_empty() {
        std::process::exit(1);
    }
}

// ------------------------------------------------------------- self-tests

#[cfg(test)]
mod tests {
    use super::*;

    fn lint_one(path: &str, src: &str) -> Vec<Violation> {
        let scan = lex(path, src);
        let mut out = Vec::new();
        rule_r1(&scan, &mut out);
        rule_r2(&scan, &mut out);
        rule_r3(&scan, &mut out);
        rule_r4(&scan, &mut out);
        rule_r7(&scan, &mut out);
        rule_r5(std::slice::from_ref(&scan), &mut out);
        for &line in &scan.bad_allows {
            out.push(Violation {
                rule: "ALLOW",
                path: scan.path.clone(),
                line,
                msg: String::new(),
            });
        }
        out
    }

    fn rules(vs: &[Violation]) -> Vec<&str> {
        vs.iter().map(|v| v.rule).collect()
    }

    #[test]
    fn r1_flags_unwrap_expect_panic_in_serving_path() {
        let fixture = r#"
fn handle(x: Option<u32>) -> u32 {
    let v = x.unwrap();
    let w = x.expect("boom");
    if v + w == 0 { panic!("zero"); }
    v
}
"#;
        let vs = lint_one("rust/src/forecast/http.rs", fixture);
        assert_eq!(rules(&vs), ["R1", "R1", "R1"], "{vs:?}");
        // Same source outside the serving path: no R1.
        let vs = lint_one("rust/src/hw/mod.rs", fixture);
        assert!(vs.is_empty(), "{vs:?}");
    }

    #[test]
    fn r1_exempts_lock_poison_unwraps_and_tests() {
        let fixture = r#"
fn poisoned(m: &std::sync::Mutex<u32>) -> u32 {
    let g = m.lock().unwrap();
    let h = handle.join().unwrap();
    *g + h
}
#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        let x: Option<u32> = None;
        x.unwrap();
    }
}
"#;
        let vs = lint_one("rust/src/forecast/pool.rs", fixture);
        assert!(vs.is_empty(), "{vs:?}");
    }

    #[test]
    fn r1_respects_allow_with_reason_only() {
        let with_reason = "fn f(x: Option<u32>) {\n    \
             x.unwrap(); // lint:allow(R1) — startup path, cannot race\n}\n";
        let vs = lint_one("rust/src/forecast/shard.rs", with_reason);
        assert!(vs.is_empty(), "{vs:?}");
        let no_reason = "fn f(x: Option<u32>) {\n    \
             x.unwrap(); // lint:allow(R1)\n}\n";
        let vs = lint_one("rust/src/forecast/shard.rs", no_reason);
        assert_eq!(rules(&vs), ["R1", "ALLOW"], "{vs:?}");
    }

    #[test]
    fn r2_flags_spawn_outside_pools() {
        let fixture = "fn f() { std::thread::spawn(|| {}); }\n";
        let vs = lint_one("rust/src/coordinator/trainer.rs", fixture);
        assert_eq!(rules(&vs), ["R2"], "{vs:?}");
        let vs = lint_one("rust/src/runtime/native/pool.rs", fixture);
        assert!(vs.is_empty(), "{vs:?}");
        let scoped = "fn f() { std::thread::scope(|s| {}); }\n";
        let vs = lint_one("rust/src/runtime/native/mod.rs", scoped);
        assert_eq!(rules(&vs), ["R2"], "{vs:?}");
    }

    #[test]
    fn r3_flags_allocation_inside_fence_only() {
        let fixture = r#"
fn cold() -> Vec<u32> {
    let v: Vec<u32> = (0..4).collect();
    v
}
// lint:hot-path-begin
fn hot(xs: &[f32], out: &mut Vec<f32>) {
    let a = Vec::new();
    let b = vec![0.0f32; 4];
    let c = xs.to_vec();
    let d = out.clone();
    let e = format!("{a:?}{b:?}{c:?}{d:?}");
    let f = Box::new(e);
    let g: Vec<f32> = xs.iter().copied().collect();
}
// lint:hot-path-end
"#;
        let vs = lint_one("rust/src/runtime/native/mod.rs", fixture);
        assert_eq!(rules(&vs), ["R3"; 7], "{vs:?}");
    }

    #[test]
    fn r3_reports_unbalanced_fence() {
        let fixture = "// lint:hot-path-begin\nfn f() {}\n";
        let vs = lint_one("rust/src/runtime/native/lanes.rs", fixture);
        assert_eq!(rules(&vs), ["R3"], "{vs:?}");
    }

    #[test]
    fn r4_requires_safety_comments() {
        let bad = "fn f(p: *const u32) -> u32 { unsafe { *p } }\n";
        let vs = lint_one("rust/src/util/allocmeter.rs", bad);
        assert_eq!(rules(&vs), ["R4"], "{vs:?}");
        let good = "fn f(p: *const u32) -> u32 {\n    \
             // SAFETY: caller guarantees p is valid.\n    \
             unsafe { *p }\n}\n";
        let vs = lint_one("rust/src/util/allocmeter.rs", good);
        assert!(vs.is_empty(), "{vs:?}");
        let bad_impl = "struct T(*const u32);\nunsafe impl Send for T {}\n";
        let vs = lint_one("rust/src/runtime/native/pool.rs", bad_impl);
        assert_eq!(rules(&vs), ["R4"], "{vs:?}");
    }

    #[test]
    fn r4_ignores_unsafe_keywords_in_strings_and_comments() {
        let fixture = "fn f() -> &'static str {\n    \
             // unsafe { not real code }\n    \
             \"unsafe { also not code }\"\n}\n";
        let vs = lint_one("rust/src/util/json.rs", fixture);
        assert!(vs.is_empty(), "{vs:?}");
    }

    #[test]
    fn r5_detects_lock_order_cycles() {
        let fixture = r#"
use std::sync::Mutex;
struct S {
    // lint:lock-name(a)
    a: Mutex<u32>,
    // lint:lock-name(b)
    b: Mutex<u32>,
}
impl S {
    fn ab(&self) {
        let g = self.a.lock().unwrap();
        let h = self.b.lock().unwrap();
        drop(h);
        drop(g);
    }
    fn ba(&self) {
        let g = self.b.lock().unwrap();
        let h = self.a.lock().unwrap();
        drop(h);
        drop(g);
    }
}
"#;
        let vs = lint_one("rust/src/forecast/pool.rs", fixture);
        assert!(rules(&vs).contains(&"R5"), "{vs:?}");
    }

    #[test]
    fn r5_accepts_consistent_order_and_temporaries() {
        let fixture = r#"
use std::sync::Mutex;
struct S {
    // lint:lock-name(a)
    a: Mutex<u32>,
    // lint:lock-name(b)
    b: Mutex<u32>,
}
impl S {
    fn ab(&self) {
        let g = self.a.lock().unwrap();
        let h = self.b.lock().unwrap();
        drop(h);
        drop(g);
    }
    fn b_then_a_released(&self) {
        *self.b.lock().unwrap() += 1;
        let g = self.a.lock().unwrap();
        drop(g);
    }
}
"#;
        let vs = lint_one("rust/src/forecast/pool.rs", fixture);
        assert!(vs.is_empty(), "{vs:?}");
    }

    #[test]
    fn r5_guard_dropped_before_second_lock_is_clean() {
        let fixture = r#"
use std::sync::Mutex;
struct S {
    // lint:lock-name(x)
    x: Mutex<u32>,
    // lint:lock-name(y)
    y: Mutex<u32>,
}
impl S {
    fn xy(&self) {
        let g = self.x.lock().unwrap();
        drop(g);
        let h = self.y.lock().unwrap();
        drop(h);
    }
    fn yx(&self) {
        let h = self.y.lock().unwrap();
        drop(h);
        let g = self.x.lock().unwrap();
        drop(g);
    }
}
"#;
        let vs = lint_one("rust/src/forecast/shard.rs", fixture);
        assert!(vs.is_empty(), "{vs:?}");
    }

    #[test]
    fn r6_flags_unregistered_and_unnamed_suites() {
        let stems = vec!["pipeline".to_string(), "ghost".to_string()];
        let cargo = "[[test]]\nname = \"pipeline\"\n";
        let ci = "run: scripts/run_named_tests.sh pipeline hourly\n";
        let vs = rule_r6_strings(&stems, cargo, ci);
        assert_eq!(rules(&vs), ["R6", "R6"], "{vs:?}");
        assert!(vs.iter().all(|v| v.path.contains("ghost")), "{vs:?}");
    }

    #[test]
    fn r7_flags_partial_cmp_unwrap() {
        let fixture = "fn f(v: &[f32]) -> f32 {\n    \
             *v.iter().max_by(|a, b| a.partial_cmp(b).unwrap()).unwrap()\n}\n";
        let vs = lint_one("benches/micro_hotpath.rs", fixture);
        assert_eq!(rules(&vs), ["R7"], "{vs:?}");
        let good = "fn f(v: &[f32]) -> f32 {\n    \
             *v.iter().max_by(|a, b| a.total_cmp(b)).unwrap()\n}\n";
        let vs = lint_one("benches/micro_hotpath.rs", good);
        assert!(vs.is_empty(), "{vs:?}");
    }

    #[test]
    fn lexer_handles_raw_strings_and_chars() {
        let fixture = "fn f() -> u32 {\n    \
             let s = r#\"panic!(\"in a raw string\")\"#;\n    \
             let c = '\\'';\n    let lt: &'static str = \"x\";\n    \
             s.len() as u32 + c as u32 + lt.len() as u32\n}\n";
        let vs = lint_one("rust/src/forecast/http.rs", fixture);
        assert!(vs.is_empty(), "{vs:?}");
    }
}
