//! §8.4 ablation: quarterly ES-RNN with vs without the level-variability
//! and c-state stabilization penalties Smyl's original submission used.
//!
//! Both variants share shapes, data, seeds and schedule; only the loss
//! terms baked into the artifact differ (`quarterly` vs `quarterly_pen`).
//! Reports val/test sMAPE and the smoothness of the learned levels'
//! implied forecasts (the penalties should trade a little fit for
//! stability — the paper's §8.4 hypothesis).
//!
//! Run with: `cargo bench --bench ablation_penalties`

use fast_esrnn::config::{Frequency, TrainConfig};
use fast_esrnn::coordinator::{EvalSplit, Trainer};
use fast_esrnn::data::{generate, GenOptions};
use fast_esrnn::runtime::{default_backend, Backend};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Mean squared step-to-step relative change of each forecast path —
/// the §8.4 "variant forecast" proxy (lower = smoother).
fn roughness(fcs: &[Vec<f32>]) -> f64 {
    let mut acc = 0.0f64;
    let mut n = 0usize;
    for fc in fcs {
        for w in fc.windows(2) {
            let rel = ((w[1] - w[0]) / w[0].max(1e-6)) as f64;
            acc += rel * rel;
            n += 1;
        }
    }
    acc / n.max(1) as f64
}

fn main() -> anyhow::Result<()> {
    let epochs = env_usize("FAST_ESRNN_EPOCHS", 8);
    let backend = default_backend()?;
    let corpus = generate(&GenOptions { scale: 100, ..Default::default() })?;

    println!("== §8.4 penalties ablation (quarterly, {epochs} epochs) ==\n");
    println!("{:<26} {:>10} {:>10} {:>12} {:>10}", "variant", "val sMAPE",
             "test sMAPE", "roughness", "loss[last]");
    for (label, key) in [("baseline (no penalties)", None),
                         ("level+cstate penalties", Some("quarterly_pen"))] {
        if let Some(k) = key {
            if backend.manifest().config(k).is_err() {
                println!("{label:<26} skipped: model key `{k}` not served by \
                          this backend (penalty variants are PJRT-only)");
                continue;
            }
        }
        let tc = TrainConfig {
            model_key: key.map(|s| s.to_string()),
            epochs,
            batch_size: 64,
            patience: 50,
            ..Default::default()
        };
        let mut trainer = Trainer::new(backend.as_ref(), Frequency::Quarterly,
                                       &corpus, tc)?;
        let report = trainer.train(false)?;
        let val = trainer.evaluate(EvalSplit::Validation)?;
        let test = trainer.evaluate(EvalSplit::Test)?;
        let fcs = trainer.forecasts(true)?;
        println!("{:<26} {:>10.3} {:>10.3} {:>12.6} {:>10.5}", label,
                 val.smape, test.smape, roughness(&fcs),
                 report.epoch_losses.last().unwrap());
    }
    println!("\npaper §8.4: penalties should favor smoother forecasts and \
              long-horizon stability (possibly at small sMAPE cost).");
    Ok(())
}
