//! Table 6 reproduction: test sMAPE broken down by data category ×
//! frequency — exercises the per-category generator structure and the
//! category one-hot input (paper §5.3).
//!
//! Also prints the Table 2/3 corpus summaries (the generator's calibration
//! against the paper's data description).
//!
//! Run with: `cargo bench --bench table6_categories`
//! Env: FAST_ESRNN_SCALE (default 100), FAST_ESRNN_EPOCHS (default 10).

use fast_esrnn::config::{TrainConfig, ALL_CATEGORIES, MODELED_FREQS};
use fast_esrnn::coordinator::{EvalSplit, Trainer};
use fast_esrnn::data::{generate, stats, GenOptions};
use fast_esrnn::metrics::MetricAccumulator;
use fast_esrnn::runtime::default_backend;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let scale = env_usize("FAST_ESRNN_SCALE", 100);
    let epochs = env_usize("FAST_ESRNN_EPOCHS", 10);
    let backend = default_backend()?;
    let corpus = generate(&GenOptions { scale, ..Default::default() })?;

    println!("== Table 2 analogue (corpus calibration) ==");
    print!("{}", stats::render_count_table(&corpus));
    println!("\n== Table 3 analogue ==");
    print!("{}", stats::render_length_table(&corpus));

    let mut accs: Vec<(String, MetricAccumulator, f64)> = Vec::new();
    for freq in MODELED_FREQS {
        let tc = TrainConfig {
            epochs,
            batch_size: 64,
            ..Default::default()
        };
        let mut trainer = Trainer::new(backend.as_ref(), freq, &corpus, tc)?;
        eprintln!("[table6] training {} on {} series…", freq.name(),
                  trainer.series_count());
        trainer.train(false)?;
        let test = trainer.evaluate(EvalSplit::Test)?;
        accs.push((freq.name().into(), test.per_category, test.smape));
    }

    println!("\n== Table 6: sMAPE by category × frequency (our corpus) ==");
    println!("{:<14} {:>8} {:>10} {:>8}", "category", "Yearly", "Quarterly",
             "Monthly");
    for cat in ALL_CATEGORIES {
        let cells: Vec<String> = accs
            .iter()
            .map(|(_, acc, _)| {
                acc.mean_smape(cat.name())
                   .map(|v| format!("{v:.2}"))
                   .unwrap_or_else(|| "-".into())
            })
            .collect();
        println!("{:<14} {:>8} {:>10} {:>8}", cat.name(), cells[0], cells[1],
                 cells[2]);
    }
    println!("{:<14} {:>8.2} {:>10.2} {:>8.2}", "Overall", accs[0].2,
             accs[1].2, accs[2].2);

    println!("\npaper Table 6 (real M4): Yearly overall 14.42, Quarterly \
              10.1, Monthly 10.81; Finance/Micro hardest, Demographic \
              easiest at monthly.");
    Ok(())
}
