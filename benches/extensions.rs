//! §8.2 / §8.5 extensions bench: train the daily (single-seasonality,
//! quarterly-structured) and hourly (dual 24h/168h seasonality) models —
//! the frequencies the paper lists as future work — and score them against
//! the seasonal-naive and Comb baselines.
//!
//! Run with: `cargo bench --bench extensions`

use fast_esrnn::baselines::{Comb, Forecaster, SeasonalNaive};
use fast_esrnn::config::{Frequency, NetworkConfig, TrainConfig};
use fast_esrnn::coordinator::{EvalSplit, Trainer};
use fast_esrnn::data::{generate, split_corpus, GenOptions};
use fast_esrnn::metrics::smape;
use fast_esrnn::runtime::{default_backend, Backend};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let backend = default_backend()?;
    let corpus = generate(&GenOptions { scale: 100, ..Default::default() })?;

    println!("== §8.2/§8.5 extension frequencies ==\n");
    println!("{:<10} {:>7} {:>8} {:>12} {:>12} {:>12}", "freq", "series",
             "epochs", "ES-RNN", "Comb", "sNaive");
    for (freq, epochs, batch) in [
        (Frequency::Daily, env_usize("FAST_ESRNN_EPOCHS", 6), 16),
        (Frequency::Hourly, env_usize("FAST_ESRNN_EPOCHS_HOURLY", 4), 4),
    ] {
        if backend.manifest().config(freq.name()).is_err() {
            println!("{:<10} skipped: not served by this backend's manifest",
                     freq.name());
            continue;
        }
        let net = NetworkConfig::for_freq(freq)?;
        let tc = TrainConfig {
            epochs,
            batch_size: batch,
            patience: 50,
            ..Default::default()
        };
        let mut trainer = Trainer::new(backend.as_ref(), freq, &corpus, tc)?;
        let n = trainer.series_count();
        eprintln!("[extensions] training {} on {n} series…", freq.name());
        trainer.train(false)?;
        let test = trainer.evaluate(EvalSplit::Test)?;

        let set = split_corpus(&corpus, &net)?;
        let mut comb = 0.0;
        let mut snaive = 0.0;
        for sp in &set.series {
            let fc = Comb.forecast(&sp.refit, net.seasonality, net.horizon);
            comb += smape(&fc, &sp.test);
            let fn_ = SeasonalNaive.forecast(&sp.refit, net.seasonality,
                                             net.horizon);
            snaive += smape(&fn_, &sp.test);
        }
        let m = set.series.len() as f64;
        println!("{:<10} {:>7} {:>8} {:>12.3} {:>12.3} {:>12.3}",
                 freq.name(), n, epochs, test.smape, comb / m, snaive / m);
    }
    println!("\nhourly uses the §8.2 dual-seasonality (24h × 168h) ES kernel \
              end-to-end: dual recurrence (native Rust or Pallas) → combined \
              deseasonalization → per-series [alpha, gamma1, gamma2, 192 \
              seasonality inits].");
    Ok(())
}
