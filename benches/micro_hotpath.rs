//! Micro-benchmarks of the L3 hot path: where does a training step's
//! wall-clock go? Feeds the §Perf optimization log in EXPERIMENTS.md.
//!
//! Cases:
//!   * batch assembly (host tensor packing)          — pure Rust
//!   * store gather / scatter                        — pure Rust
//!   * train_step execute (end-to-end via PJRT)      — XLA compute
//!   * predict execute                               — XLA compute
//!   * classical primer                              — pure Rust
//!   * forecast-service single-request round trip    — threading + XLA
//!
//! Run with: `cargo bench --bench micro_hotpath`

use fast_esrnn::config::{Frequency, TrainConfig};
use fast_esrnn::coordinator::{Batcher, Trainer};
use fast_esrnn::data::{generate, GenOptions};
use fast_esrnn::hw;
use fast_esrnn::runtime::{default_backend, Backend};
use fast_esrnn::util::bench::{bench, header};

fn main() -> anyhow::Result<()> {
    let backend = default_backend()?;
    let corpus = generate(&GenOptions { scale: 100, ..Default::default() });
    let freq = Frequency::Quarterly;
    let b = 64usize;
    let tc = TrainConfig { batch_size: b, ..Default::default() };
    let mut trainer = Trainer::new(backend.as_ref(), freq, &corpus, tc)?;
    let n = trainer.series_count();
    println!("{} | quarterly, {n} series, batch {b}\n\n{}",
             backend.platform(), header());

    let mut sched = Batcher::new(n, b, 3);
    let epoch = sched.epoch();
    let batch = epoch[0].clone();

    // Warm the executable caches.
    trainer.train_step_batch(&batch)?;
    let _ = trainer.forecasts(false)?;

    // --- store gather ---
    let idx = batch.indices.clone();
    let store = trainer.store.clone();
    let st = bench("store.gather_batch (B=64)", 3, 200, || {
        let _ = store.gather_batch(&idx).unwrap();
    });
    println!("{}", st.row(b as f64));

    // --- primer ---
    let series = trainer.set.series[0].train.clone();
    let st = bench("hw.primer (C=72, S=4)", 3, 500, || {
        let _ = hw::primer(&series, 4);
    });
    println!("{}", st.row(1.0));

    // --- full train step ---
    let st = bench("train_step end-to-end (B=64)", 1, 10, || {
        trainer.train_step_batch(&batch).unwrap();
    });
    println!("{}", st.row(b as f64));

    // --- predict pass over the whole pool ---
    let st = bench("predict all series", 1, 5, || {
        let _ = trainer.forecasts(false).unwrap();
    });
    println!("{}", st.row(n as f64));

    // --- backend phase breakdown accumulated so far ---
    let stats = backend.stats();
    println!("\nbackend totals: {} executions | pack {:.3}s | execute {:.3}s \
              | unpack {:.3}s | {} compiles ({:.2}s)",
             stats.executions, stats.pack_secs, stats.execute_secs,
             stats.unpack_secs, stats.compiles, stats.compile_secs);
    println!("{}", trainer.telemetry.report());
    Ok(())
}
