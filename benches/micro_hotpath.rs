//! Micro-benchmarks of the L3 hot path: where does a training step's
//! wall-clock go? Feeds the §Perf optimization log in EXPERIMENTS.md and
//! the CI perf gate (`scripts/bench_gate.sh`).
//!
//! Sections:
//!   * scalar vs. lane-vectorized train step, per Table-1 frequency —
//!     the PR-3 SIMD speedup trajectory; emitted as BENCH_3.json when
//!     `FAST_ESRNN_BENCH_JSON=<path>` is set
//!   * persistent-pool vs. spawn-per-call train step (PR-6), with
//!     steady-state allocations/step and spawns/step measured by the
//!     counting allocator; emitted as BENCH_6.json when
//!     `FAST_ESRNN_BENCH6_JSON=<path>` is set
//!   * batch assembly / store gather / primer / end-to-end train and
//!     predict on the default backend (skipped in quick mode)
//!
//! Env:
//!   FAST_ESRNN_QUICK=1        — CI mode: fewer steps, smaller batches,
//!                               kernel comparison only
//!   FAST_ESRNN_BENCH_JSON=p   — write the kernel-comparison summary to p
//!   FAST_ESRNN_BENCH6_JSON=p  — write the pool/steady-state summary to p
//!
//! Run with: `cargo bench --bench micro_hotpath`

use std::collections::HashMap;

use fast_esrnn::config::{Frequency, TrainConfig};
use fast_esrnn::coordinator::{Batcher, Trainer};
use fast_esrnn::data::{generate, GenOptions};
use fast_esrnn::hw;
use fast_esrnn::runtime::{default_backend, Backend, ComputeMode,
                          HostTensor, Manifest, NativeBackend};
use fast_esrnn::util::allocmeter::{self, CountingAlloc};
use fast_esrnn::util::bench::{bench, fmt_secs, header};
use fast_esrnn::util::json::Json;
use fast_esrnn::util::prop::gen_positive_series_dual;
use fast_esrnn::util::rng::Rng;

// Counts every heap allocation in the process so the BENCH_6 section can
// report allocations/step on the steady-state hot path. Pass-through to
// the system allocator otherwise (one relaxed atomic add per alloc).
#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

/// Largest manifest batch size ≤ both `cap` and the series count.
fn pick_batch(n_series: usize, cap: usize) -> usize {
    let mut b = 1usize;
    while b * 2 <= n_series.min(cap) {
        b *= 2;
    }
    b
}

/// Synthetic batch + initial state for direct `train_step_inplace`
/// benchmarking (the zero-allocation entry point — `Trainer` goes
/// through `execute_named`, which hands back freshly allocated output
/// tensors by contract).
fn steady_scenario(backend: &NativeBackend, freq: &str, b: usize, seed: u64)
                   -> anyhow::Result<(String, HashMap<String, HostTensor>,
                                      HashMap<String, HostTensor>)> {
    let cfg = backend.manifest().config(freq)?.clone();
    let w = cfg.seasonality + cfg.seasonality2;
    let mut rng = Rng::new(seed);
    let mut y = Vec::new();
    for _ in 0..b {
        y.extend(gen_positive_series_dual(&mut rng, cfg.length,
                                          cfg.seasonality,
                                          cfg.seasonality2));
    }
    let mut cat = vec![0.0f32; b * 6];
    for i in 0..b {
        cat[i * 6 + i % 6] = 1.0;
    }
    let data = HashMap::from([
        ("data.y".to_string(), HostTensor::new(vec![b, cfg.length], y)?),
        ("data.cat".to_string(), HostTensor::new(vec![b, 6], cat)?),
        ("data.mask".to_string(),
         HostTensor::new(vec![b], vec![1.0; b])?),
        ("lr".to_string(), HostTensor::scalar(1e-3)),
    ]);

    let rnn = backend.execute_init(freq, seed)?;
    let mut state: HashMap<String, HostTensor> =
        rnn.into_iter().map(|(n, t)| (format!("params.{n}"), t)).collect();
    state.insert("params.series.alpha_logit".into(),
                 HostTensor::new(vec![b], vec![-0.5; b])?);
    state.insert("params.series.gamma_logit".into(),
                 HostTensor::new(vec![b], vec![-1.0; b])?);
    if cfg.seasonality2 > 0 {
        state.insert("params.series.gamma2_logit".into(),
                     HostTensor::new(vec![b], vec![-1.0; b])?);
    }
    state.insert("params.series.log_s_init".into(),
                 HostTensor::new(vec![b, w], vec![0.0; b * w])?);
    let keys: Vec<String> = state.keys().cloned().collect();
    for k in &keys {
        let z = HostTensor::zeros(state[k].shape.clone());
        state.insert(k.replace("params.", "opt.m."), z.clone());
        state.insert(k.replace("params.", "opt.v."), z);
    }
    state.insert("opt.step".into(), HostTensor::scalar(0.0));
    Ok((Manifest::program_name(freq, b, "train_step"), data, state))
}

/// Median seconds per train step for one backend mode.
fn time_train_step(backend: &NativeBackend, freq: Frequency, corpus: &fast_esrnn::data::Corpus,
                   b: usize, warmup: usize, iters: usize)
                   -> anyhow::Result<f64> {
    let tc = TrainConfig { batch_size: b, epochs: 1, ..Default::default() };
    let mut trainer = Trainer::new(backend, freq, corpus, tc)?;
    let n = trainer.series_count();
    let mut sched = Batcher::new(n, b, 7);
    let batch = sched.epoch().remove(0);
    let st = bench("step", warmup, iters, || {
        trainer.train_step_batch(&batch).unwrap();
    });
    Ok(st.median)
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("FAST_ESRNN_QUICK").is_ok();
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    // scale 50 keeps every frequency populated (hourly: 9 series — one
    // full lane group) without making trainer setup dominate.
    let corpus = generate(&GenOptions { scale: 50, ..Default::default() })?;

    // ---- scalar vs. lane-vectorized train step, per frequency ----
    let cap = if quick { 16 } else { 64 };
    // Quick mode still takes the median of 5 timed steps: the gate in CI
    // hard-fails on this number, and a median-of-2 would let one
    // noisy-neighbor stall on a shared runner flip the verdict.
    let (warmup, iters) = if quick { (1, 5) } else { (2, 8) };
    println!("== lane-vectorized vs scalar native train step ==");
    println!("{} threads | batch cap {cap} | {iters} timed steps\n", threads);
    println!("{:<10} {:>6} {:>14} {:>14} {:>9}",
             "freq", "batch", "scalar/step", "lanes/step", "speedup");
    let freqs = [Frequency::Yearly, Frequency::Quarterly, Frequency::Monthly,
                 Frequency::Daily, Frequency::Hourly];
    let scalar_backend =
        NativeBackend::with_threads_mode(threads, ComputeMode::Scalar);
    let lane_backend =
        NativeBackend::with_threads_mode(threads, ComputeMode::Lanes);
    let mut freq_rows: Vec<(&'static str, usize, f64, f64, f64)> = Vec::new();
    for freq in freqs {
        // Probe the series count cheaply via a b=1 trainer.
        let probe = Trainer::new(&scalar_backend, freq, &corpus,
                                 TrainConfig { batch_size: 1, epochs: 1,
                                               ..Default::default() })?;
        let b = pick_batch(probe.series_count(), cap);
        drop(probe);
        let scalar_s =
            time_train_step(&scalar_backend, freq, &corpus, b, warmup, iters)?;
        let lanes_s =
            time_train_step(&lane_backend, freq, &corpus, b, warmup, iters)?;
        let speedup = scalar_s / lanes_s;
        println!("{:<10} {:>6} {:>14} {:>14} {:>8.2}x", freq.name(), b,
                 fmt_secs(scalar_s), fmt_secs(lanes_s), speedup);
        freq_rows.push((freq.name(), b, scalar_s, lanes_s, speedup));
    }
    let (best_freq, _, _, _, best) = freq_rows
        .iter()
        .copied()
        .max_by(|a, b| a.4.total_cmp(&b.4))
        .unwrap();
    println!("\nmax speedup: {best:.2}x ({best_freq})");

    if let Ok(path) = std::env::var("FAST_ESRNN_BENCH_JSON") {
        let freq_objs: Vec<(&str, Json)> = freq_rows
            .iter()
            .map(|(name, b, sc, la, sp)| {
                (*name,
                 Json::obj(vec![
                     ("batch", Json::num(*b as f64)),
                     ("scalar_ns_per_step", Json::num(sc * 1e9)),
                     ("lanes_ns_per_step", Json::num(la * 1e9)),
                     ("speedup", Json::num(*sp)),
                 ]))
            })
            .collect();
        let doc = Json::obj(vec![
            ("bench", Json::str("micro_hotpath")),
            ("quick", Json::Bool(quick)),
            ("threads", Json::num(threads as f64)),
            ("frequencies", Json::obj(freq_objs)),
            ("max_speedup", Json::num(best)),
            ("max_speedup_freq", Json::str(best_freq)),
        ]);
        std::fs::write(&path, format!("{doc}\n"))?;
        println!("wrote {path}");
    }

    // ---- persistent pool vs spawn-per-call steady state (PR 6) ----
    // Clamp so the comparison exercises the pool even on 1-core runners
    // without oversubscribing wide ones: b=16 is only 2 lane groups.
    let pool_threads = threads.clamp(2, 8);
    let (p_warm, p_iters) = if quick { (3, 8) } else { (3, 30) };
    println!("\n== persistent pool vs spawn-per-call train step ==");
    println!("{pool_threads} pool threads | batch 16 | {p_iters} timed \
              steps (train_step_inplace)\n");
    println!("{:<10} {:>14} {:>14} {:>9} {:>12} {:>12}",
             "freq", "spawn/step", "pooled/step", "speedup",
             "allocs/step", "spawns/step");
    let pooled_backend =
        NativeBackend::with_threads_mode(pool_threads, ComputeMode::Lanes);
    let spawn_backend =
        NativeBackend::with_threads_mode_spawn(pool_threads,
                                               ComputeMode::Lanes);
    let mut pool_rows: Vec<(&'static str, f64, f64, f64, f64, f64)> =
        Vec::new();
    for freq in freqs {
        let name = freq.name();
        let (prog, data, mut st_pool) =
            steady_scenario(&pooled_backend, name, 16, 11)?;
        let mut st_spawn = st_pool.clone();
        for _ in 0..p_warm {
            pooled_backend.train_step_inplace(&prog, &data, &mut st_pool)?;
            spawn_backend.train_step_inplace(&prog, &data, &mut st_spawn)?;
        }
        let t = bench("pooled", 0, p_iters, || {
            pooled_backend
                .train_step_inplace(&prog, &data, &mut st_pool)
                .unwrap();
        });
        let pooled_s = t.median;
        let t = bench("spawn", 0, p_iters, || {
            spawn_backend
                .train_step_inplace(&prog, &data, &mut st_spawn)
                .unwrap();
        });
        let spawn_s = t.median;
        // Allocation/spawn counting in a bare loop: `bench` keeps its own
        // sample vector, which would otherwise be charged to the step.
        let a0 = allocmeter::allocations();
        let s0 = pooled_backend.stats().spawns;
        for _ in 0..p_iters {
            pooled_backend.train_step_inplace(&prog, &data, &mut st_pool)?;
        }
        let allocs_per_step =
            (allocmeter::allocations() - a0) as f64 / p_iters as f64;
        let spawns_per_step = (pooled_backend.stats().spawns - s0) as f64
            / p_iters as f64;
        let speedup = spawn_s / pooled_s;
        println!("{:<10} {:>14} {:>14} {:>8.2}x {:>12.1} {:>12.1}",
                 name, fmt_secs(spawn_s), fmt_secs(pooled_s), speedup,
                 allocs_per_step, spawns_per_step);
        pool_rows.push((name, spawn_s, pooled_s, speedup, allocs_per_step,
                        spawns_per_step));
    }
    let max_pooled = pool_rows
        .iter()
        .map(|r| r.3)
        .fold(f64::NEG_INFINITY, f64::max);
    println!("\nmax pooled speedup: {max_pooled:.2}x");

    if let Ok(path) = std::env::var("FAST_ESRNN_BENCH6_JSON") {
        let freq_objs: Vec<(&str, Json)> = pool_rows
            .iter()
            .map(|(name, sp, po, su, al, th)| {
                (*name,
                 Json::obj(vec![
                     ("batch", Json::num(16.0)),
                     ("spawn_ns_per_step", Json::num(sp * 1e9)),
                     ("pooled_ns_per_step", Json::num(po * 1e9)),
                     ("pooled_speedup", Json::num(*su)),
                     ("allocs_per_step", Json::num(*al)),
                     ("spawns_per_step", Json::num(*th)),
                 ]))
            })
            .collect();
        let doc = Json::obj(vec![
            ("bench", Json::str("micro_hotpath/steady_state")),
            ("quick", Json::Bool(quick)),
            ("pool_threads", Json::num(pool_threads as f64)),
            ("frequencies", Json::obj(freq_objs)),
            ("max_pooled_speedup", Json::num(max_pooled)),
        ]);
        std::fs::write(&path, format!("{doc}\n"))?;
        println!("wrote {path}");
    }
    if quick {
        return Ok(());
    }

    // ---- legacy hot-path cases on the default backend ----
    // Regenerated at the historical scale (100) so these rows stay
    // comparable with previously logged EXPERIMENTS.md numbers.
    let corpus = generate(&GenOptions { scale: 100, ..Default::default() })?;
    let backend = default_backend()?;
    let freq = Frequency::Quarterly;
    let b = 64usize;
    let tc = TrainConfig { batch_size: b, ..Default::default() };
    let mut trainer = Trainer::new(backend.as_ref(), freq, &corpus, tc)?;
    let n = trainer.series_count();
    println!("\n{} | quarterly, {n} series, batch {b}\n\n{}",
             backend.platform(), header());

    let mut sched = Batcher::new(n, b, 3);
    let epoch = sched.epoch();
    let batch = epoch[0].clone();

    // Warm the executable caches.
    trainer.train_step_batch(&batch)?;
    let _ = trainer.forecasts(false)?;

    // --- store gather ---
    let idx = batch.indices.clone();
    let store = trainer.store.clone();
    let st = bench("store.gather_batch (B=64)", 3, 200, || {
        let _ = store.gather_batch(&idx).unwrap();
    });
    println!("{}", st.row(b as f64));

    // --- primer ---
    let series = trainer.set.series[0].train.clone();
    let st = bench("hw.primer (C=72, S=4)", 3, 500, || {
        let _ = hw::primer(&series, 4);
    });
    println!("{}", st.row(1.0));

    // --- full train step ---
    let st = bench("train_step end-to-end (B=64)", 1, 10, || {
        trainer.train_step_batch(&batch).unwrap();
    });
    println!("{}", st.row(b as f64));

    // --- predict pass over the whole pool ---
    let st = bench("predict all series", 1, 5, || {
        let _ = trainer.forecasts(false).unwrap();
    });
    println!("{}", st.row(n as f64));

    // --- backend phase breakdown accumulated so far ---
    let stats = backend.stats();
    println!("\nbackend totals: {} executions | pack {:.3}s | execute {:.3}s \
              | unpack {:.3}s | {} compiles ({:.2}s)",
             stats.executions, stats.pack_secs, stats.execute_secs,
             stats.unpack_secs, stats.compiles, stats.compile_secs);
    println!("{}", trainer.telemetry.report());
    Ok(())
}
