//! Micro-benchmarks of the L3 hot path: where does a training step's
//! wall-clock go? Feeds the §Perf optimization log in EXPERIMENTS.md and
//! the CI perf gate (`scripts/bench_gate.sh`).
//!
//! Sections:
//!   * scalar vs. lane-vectorized train step, per Table-1 frequency —
//!     the PR-3 SIMD speedup trajectory; emitted as BENCH_3.json when
//!     `FAST_ESRNN_BENCH_JSON=<path>` is set
//!   * batch assembly / store gather / primer / end-to-end train and
//!     predict on the default backend (skipped in quick mode)
//!
//! Env:
//!   FAST_ESRNN_QUICK=1        — CI mode: fewer steps, smaller batches,
//!                               kernel comparison only
//!   FAST_ESRNN_BENCH_JSON=p   — write the kernel-comparison summary to p
//!
//! Run with: `cargo bench --bench micro_hotpath`

use fast_esrnn::config::{Frequency, TrainConfig};
use fast_esrnn::coordinator::{Batcher, Trainer};
use fast_esrnn::data::{generate, GenOptions};
use fast_esrnn::hw;
use fast_esrnn::runtime::{default_backend, Backend, ComputeMode,
                          NativeBackend};
use fast_esrnn::util::bench::{bench, fmt_secs, header};
use fast_esrnn::util::json::Json;

/// Largest manifest batch size ≤ both `cap` and the series count.
fn pick_batch(n_series: usize, cap: usize) -> usize {
    let mut b = 1usize;
    while b * 2 <= n_series.min(cap) {
        b *= 2;
    }
    b
}

/// Median seconds per train step for one backend mode.
fn time_train_step(backend: &NativeBackend, freq: Frequency, corpus: &fast_esrnn::data::Corpus,
                   b: usize, warmup: usize, iters: usize)
                   -> anyhow::Result<f64> {
    let tc = TrainConfig { batch_size: b, epochs: 1, ..Default::default() };
    let mut trainer = Trainer::new(backend, freq, corpus, tc)?;
    let n = trainer.series_count();
    let mut sched = Batcher::new(n, b, 7);
    let batch = sched.epoch().remove(0);
    let st = bench("step", warmup, iters, || {
        trainer.train_step_batch(&batch).unwrap();
    });
    Ok(st.median)
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("FAST_ESRNN_QUICK").is_ok();
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    // scale 50 keeps every frequency populated (hourly: 9 series — one
    // full lane group) without making trainer setup dominate.
    let corpus = generate(&GenOptions { scale: 50, ..Default::default() })?;

    // ---- scalar vs. lane-vectorized train step, per frequency ----
    let cap = if quick { 16 } else { 64 };
    // Quick mode still takes the median of 5 timed steps: the gate in CI
    // hard-fails on this number, and a median-of-2 would let one
    // noisy-neighbor stall on a shared runner flip the verdict.
    let (warmup, iters) = if quick { (1, 5) } else { (2, 8) };
    println!("== lane-vectorized vs scalar native train step ==");
    println!("{} threads | batch cap {cap} | {iters} timed steps\n", threads);
    println!("{:<10} {:>6} {:>14} {:>14} {:>9}",
             "freq", "batch", "scalar/step", "lanes/step", "speedup");
    let freqs = [Frequency::Yearly, Frequency::Quarterly, Frequency::Monthly,
                 Frequency::Daily, Frequency::Hourly];
    let scalar_backend =
        NativeBackend::with_threads_mode(threads, ComputeMode::Scalar);
    let lane_backend =
        NativeBackend::with_threads_mode(threads, ComputeMode::Lanes);
    let mut freq_rows: Vec<(&'static str, usize, f64, f64, f64)> = Vec::new();
    for freq in freqs {
        // Probe the series count cheaply via a b=1 trainer.
        let probe = Trainer::new(&scalar_backend, freq, &corpus,
                                 TrainConfig { batch_size: 1, epochs: 1,
                                               ..Default::default() })?;
        let b = pick_batch(probe.series_count(), cap);
        drop(probe);
        let scalar_s =
            time_train_step(&scalar_backend, freq, &corpus, b, warmup, iters)?;
        let lanes_s =
            time_train_step(&lane_backend, freq, &corpus, b, warmup, iters)?;
        let speedup = scalar_s / lanes_s;
        println!("{:<10} {:>6} {:>14} {:>14} {:>8.2}x", freq.name(), b,
                 fmt_secs(scalar_s), fmt_secs(lanes_s), speedup);
        freq_rows.push((freq.name(), b, scalar_s, lanes_s, speedup));
    }
    let (best_freq, _, _, _, best) = freq_rows
        .iter()
        .copied()
        .max_by(|a, b| a.4.partial_cmp(&b.4).unwrap())
        .unwrap();
    println!("\nmax speedup: {best:.2}x ({best_freq})");

    if let Ok(path) = std::env::var("FAST_ESRNN_BENCH_JSON") {
        let freq_objs: Vec<(&str, Json)> = freq_rows
            .iter()
            .map(|(name, b, sc, la, sp)| {
                (*name,
                 Json::obj(vec![
                     ("batch", Json::num(*b as f64)),
                     ("scalar_ns_per_step", Json::num(sc * 1e9)),
                     ("lanes_ns_per_step", Json::num(la * 1e9)),
                     ("speedup", Json::num(*sp)),
                 ]))
            })
            .collect();
        let doc = Json::obj(vec![
            ("bench", Json::str("micro_hotpath")),
            ("quick", Json::Bool(quick)),
            ("threads", Json::num(threads as f64)),
            ("frequencies", Json::obj(freq_objs)),
            ("max_speedup", Json::num(best)),
            ("max_speedup_freq", Json::str(best_freq)),
        ]);
        std::fs::write(&path, format!("{doc}\n"))?;
        println!("wrote {path}");
    }
    if quick {
        return Ok(());
    }

    // ---- legacy hot-path cases on the default backend ----
    // Regenerated at the historical scale (100) so these rows stay
    // comparable with previously logged EXPERIMENTS.md numbers.
    let corpus = generate(&GenOptions { scale: 100, ..Default::default() })?;
    let backend = default_backend()?;
    let freq = Frequency::Quarterly;
    let b = 64usize;
    let tc = TrainConfig { batch_size: b, ..Default::default() };
    let mut trainer = Trainer::new(backend.as_ref(), freq, &corpus, tc)?;
    let n = trainer.series_count();
    println!("\n{} | quarterly, {n} series, batch {b}\n\n{}",
             backend.platform(), header());

    let mut sched = Batcher::new(n, b, 3);
    let epoch = sched.epoch();
    let batch = epoch[0].clone();

    // Warm the executable caches.
    trainer.train_step_batch(&batch)?;
    let _ = trainer.forecasts(false)?;

    // --- store gather ---
    let idx = batch.indices.clone();
    let store = trainer.store.clone();
    let st = bench("store.gather_batch (B=64)", 3, 200, || {
        let _ = store.gather_batch(&idx).unwrap();
    });
    println!("{}", st.row(b as f64));

    // --- primer ---
    let series = trainer.set.series[0].train.clone();
    let st = bench("hw.primer (C=72, S=4)", 3, 500, || {
        let _ = hw::primer(&series, 4);
    });
    println!("{}", st.row(1.0));

    // --- full train step ---
    let st = bench("train_step end-to-end (B=64)", 1, 10, || {
        trainer.train_step_batch(&batch).unwrap();
    });
    println!("{}", st.row(b as f64));

    // --- predict pass over the whole pool ---
    let st = bench("predict all series", 1, 5, || {
        let _ = trainer.forecasts(false).unwrap();
    });
    println!("{}", st.row(n as f64));

    // --- backend phase breakdown accumulated so far ---
    let stats = backend.stats();
    println!("\nbackend totals: {} executions | pack {:.3}s | execute {:.3}s \
              | unpack {:.3}s | {} compiles ({:.2}s)",
             stats.executions, stats.pack_secs, stats.execute_secs,
             stats.unpack_secs, stats.compiles, stats.compile_secs);
    println!("{}", trainer.telemetry.report());
    Ok(())
}
