//! Table 5 reproduction: training run-time, per-series ("CPU-style",
//! batch = 1 — how Smyl's original C++ trained) vs vectorized batched
//! execution across batch sizes — the paper's headline 322×/113× speedup
//! mechanism.
//!
//! We report per-epoch wall-clock extrapolated from measured steps plus
//! the speedup factor of each batch size over B=1. Absolute numbers are
//! CPU-PJRT, not GPU; the *shape* (orders-of-magnitude gain from
//! vectorization, growing with batch size) is the reproduced claim.
//!
//! Run with: `cargo bench --bench table5_speedup`
//! Env: FAST_ESRNN_STEPS (timed steps per config, default 6);
//!      FAST_ESRNN_QUICK=1 (CI mode: batch ladder {1, 8, 64}, 2 steps).

use fast_esrnn::config::{Frequency, TrainConfig};
use fast_esrnn::coordinator::{Batcher, Trainer};
use fast_esrnn::data::{generate, GenOptions};
use fast_esrnn::runtime::{default_backend, Backend};
use fast_esrnn::util::bench::fmt_secs;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("FAST_ESRNN_QUICK").is_ok();
    let steps = env_usize("FAST_ESRNN_STEPS", if quick { 2 } else { 6 });
    let backend = default_backend()?;
    println!("backend: {} | {} timed steps per config\n",
             backend.platform(), steps);
    // Generous corpus so every batch size has enough distinct series.
    let corpus = generate(&GenOptions { scale: 50, ..Default::default() })?;

    println!("== Table 5 analogue: per-epoch training time vs batch size ==");
    println!("{:<10} {:>6} {:>7} {:>14} {:>16} {:>12} {:>9}",
             "freq", "batch", "series", "per-step", "series/s", "epoch est",
             "speedup");

    for freq in [Frequency::Quarterly, Frequency::Monthly, Frequency::Yearly] {
        let mut batches = backend
            .manifest()
            .available_batches(freq.name(), "train_step");
        if quick {
            // CI mode: endpoints of the ladder are enough to show the
            // orders-of-magnitude vectorization gain.
            batches.retain(|b| [1usize, 8, 64].contains(b));
        }
        let mut per_series_b1: Option<f64> = None;
        for &b in &batches {
            let tc = TrainConfig {
                batch_size: b,
                epochs: 1,
                ..Default::default()
            };
            let mut trainer = Trainer::new(backend.as_ref(), freq, &corpus, tc)?;
            let n = trainer.series_count();
            let mut sched = Batcher::new(n, b, 7);
            let epoch = sched.epoch();

            // Warmup (includes XLA compile) then timed steps.
            trainer.train_step_batch(&epoch[0])?;
            let t0 = std::time::Instant::now();
            let mut done = 0usize;
            for batch in epoch.iter().cycle().skip(1) {
                trainer.train_step_batch(batch)?;
                done += 1;
                if done >= steps {
                    break;
                }
            }
            let per_step = t0.elapsed().as_secs_f64() / done as f64;
            let series_per_sec = b as f64 / per_step;
            let sec_per_series = per_step / b as f64;
            if b == 1 {
                per_series_b1 = Some(sec_per_series);
            }
            let speedup = per_series_b1
                .map(|base| base / sec_per_series)
                .unwrap_or(1.0);
            let epoch_est = sec_per_series * n as f64;
            println!("{:<10} {:>6} {:>7} {:>14} {:>16.1} {:>12} {:>8.1}x",
                     freq.name(), b, n, fmt_secs(per_step), series_per_sec,
                     fmt_secs(epoch_est), speedup);
        }
        println!();
    }

    println!("paper Table 5 (GPU vs 2×6/2×4-worker CPU, 15 epochs): \
              quarterly 2880s -> 8.94s (322x), monthly 3600s -> 31.91s (113x).");
    println!("our mechanism check: same algorithm, same backend, batching \
              alone must deliver orders of magnitude.");
    Ok(())
}
