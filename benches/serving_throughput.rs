//! Serving-stack throughput bench: requests/sec and p95 latency of the
//! dynamic-batching forecast pool with 1 worker vs N workers, same
//! per-worker backend (1 compute thread each, so pool parallelism is the
//! only parallelism being measured).
//!
//! Feeds the CI perf gate (`scripts/bench_gate.sh`): emitted as
//! BENCH_4.json when `FAST_ESRNN_BENCH_JSON=<path>` is set; the gate
//! fails when the N-worker pool stops beating the single-worker service
//! by the committed floor (`benches/bench4_baseline.json`).
//!
//! Env:
//!   FAST_ESRNN_QUICK=1        — CI mode: fewer requests
//!   FAST_ESRNN_BENCH_JSON=p   — write the summary JSON to p
//!
//! Run with: `cargo bench --bench serving_throughput`

use std::time::{Duration, Instant};

use fast_esrnn::config::{Frequency, TrainConfig};
use fast_esrnn::coordinator::{ModelState, Trainer};
use fast_esrnn::data::{generate, GenOptions, Series};
use fast_esrnn::forecast::{ForecastRequest, ForecastService, ServiceOptions};
use fast_esrnn::runtime::{Backend, NativeBackend};
use fast_esrnn::util::json::Json;

const FREQ: Frequency = Frequency::Quarterly;
const CLIENTS: usize = 4;

/// Fire `n_req` requests from `CLIENTS` client threads at a pool of
/// `workers` single-compute-thread workers; returns (req/s, p95 secs).
fn run_load(state: &ModelState, candidates: &[Series], workers: usize,
            n_req: usize) -> anyhow::Result<(f64, f64)> {
    let service = ForecastService::start(
        || Ok(Box::new(NativeBackend::with_threads(1)) as Box<dyn Backend>),
        FREQ,
        state.clone(),
        ServiceOptions {
            workers,
            batch_window: Duration::from_millis(1),
            max_batch: 8,
            queue_limit: 0,
            ..Default::default()
        },
    )?;
    let per = n_req / CLIENTS;
    let t0 = Instant::now();
    let mut joins = Vec::with_capacity(CLIENTS);
    for c in 0..CLIENTS {
        let handle = service.handle.clone();
        let reqs: Vec<ForecastRequest> = (0..per)
            .map(|i| {
                let s = &candidates[(c * per + i) % candidates.len()];
                ForecastRequest {
                    id: format!("{c}-{i}"),
                    values: s.values.clone(),
                    category: s.category,
                }
            })
            .collect();
        joins.push(std::thread::spawn(move || {
            let rxs: Vec<_> = reqs
                .into_iter()
                .map(|r| handle.submit(r).unwrap())
                .collect();
            for rx in rxs {
                rx.recv().unwrap().unwrap();
            }
        }));
    }
    for j in joins {
        j.join().expect("client thread panicked");
    }
    let secs = t0.elapsed().as_secs_f64();
    let st = service.handle.stats()?;
    assert_eq!(st.requests, (per * CLIENTS) as u64, "dropped requests");
    Ok(((per * CLIENTS) as f64 / secs, st.total.p95))
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("FAST_ESRNN_QUICK").is_ok();
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let n_req = if quick { 256 } else { 1024 };
    let pool_workers = (threads / 2).clamp(2, 4);

    // A small trained model + request series it never saw.
    let corpus = generate(&GenOptions { scale: 400, ..Default::default() })?;
    let tc = TrainConfig {
        epochs: 1,
        batch_size: 16,
        patience: 50,
        ..Default::default()
    };
    let backend = NativeBackend::new();
    let mut trainer = Trainer::new(&backend, FREQ, &corpus, tc)?;
    trainer.train(false)?;
    let state = trainer.state.clone();
    drop(trainer);
    let candidates: Vec<Series> = generate(&GenOptions {
        scale: 300,
        seed: 777,
        freqs: Some(vec![FREQ]),
    })?
    .series
    .into_iter()
    .filter(|s| s.len() >= 72)
    .collect();
    assert!(!candidates.is_empty());

    println!("== serving throughput: 1 vs {pool_workers} workers ==");
    println!("{threads} machine threads | {n_req} requests | {CLIENTS} \
              clients | 1 compute thread per worker\n");
    println!("{:<10} {:>12} {:>12}", "workers", "req/s", "p95");
    let (rps_1, p95_1) = run_load(&state, &candidates, 1, n_req)?;
    println!("{:<10} {:>12.1} {:>10.2}ms", 1, rps_1, p95_1 * 1e3);
    let (rps_n, p95_n) = run_load(&state, &candidates, pool_workers, n_req)?;
    println!("{:<10} {:>12.1} {:>10.2}ms", pool_workers, rps_n, p95_n * 1e3);
    let speedup = rps_n / rps_1;
    println!("\npool speedup: {speedup:.2}x requests/sec");

    if let Ok(path) = std::env::var("FAST_ESRNN_BENCH_JSON") {
        let row = |workers: usize, rps: f64, p95: f64| {
            Json::obj(vec![
                ("workers", Json::num(workers as f64)),
                ("rps", Json::num(rps)),
                ("p95_ms", Json::num(p95 * 1e3)),
            ])
        };
        let doc = Json::obj(vec![
            ("bench", Json::str("serving_throughput")),
            ("quick", Json::Bool(quick)),
            ("threads", Json::num(threads as f64)),
            ("n_requests", Json::num(n_req as f64)),
            ("single", row(1, rps_1, p95_1)),
            ("pool", row(pool_workers, rps_n, p95_n)),
            ("pool_speedup", Json::num(speedup)),
        ]);
        std::fs::write(&path, format!("{doc}\n"))?;
        println!("wrote {path}");
    }
    Ok(())
}
