//! BENCH_5 — HTTP front-end throughput: what the PR-5 serving work
//! bought on the wire.
//!
//! Two comparisons, both same-machine ratios (stable across runner
//! hardware generations in a way absolute req/s are not):
//!
//! * **keep-alive vs connection-per-request** — the same clients drive
//!   the same server through one persistent connection each
//!   (`HttpClient`) vs a fresh TCP connection per request
//!   (`http_request`). Measured on `GET /v1/healthz` (pure wire
//!   overhead — the connection tax is the whole story) and on
//!   `POST /v1/forecast` (wire + model compute, informational).
//! * **sharded vs single-stack** — the same total worker budget as one
//!   stack (1×4 workers) vs four consistent-hash shards (4×1), same
//!   keep-alive load; reports req/s and client-observed p95.
//!
//! A third section measures **BENCH_8 — metrics scrape overhead**: the
//! same keep-alive forecast load with and without a 10 Hz `/v1/metrics`
//! scraper running, reporting the p95 overhead ratio. Observability
//! must be cheap enough to leave on.
//!
//! A fourth section measures **BENCH_9 — hedged reads vs a slow
//! replica**: a 3-shard ring where one shard serves every forecast
//! 50 ms late (an injected [`ShardClient`] wrapper — the distributed
//! layer cannot tell it from a remote with a sick disk). Unhedged
//! (R = 1), every key owned by the slow shard pays the full delay and
//! p99 *is* the delay; hedged (R = 2, timer at the rolling p95), the
//! same traffic escapes to the key's healthy replica and p99 collapses
//! to the hedge delay. The gate requires hedging to beat unhedged p99
//! by the committed factor.
//!
//! A fifth section measures **BENCH_10 — stateful series routes**: the
//! observe throughput of `POST /v1/series/{id}/observe` (a µs-scale ES
//! update, no RNN), the p95 of `GET /v1/series/{id}/forecast` on a
//! pure read load (every read after the first is a forecast-cache
//! hit), and the same read p95 under a 50% observe mix (every write
//! invalidates the series' cached forecast, so half the reads
//! recompute). The gate caps how much the write mix may inflate the
//! read tail — live updates must not make stateful reads expensive.
//!
//! Feeds the CI perf gate (`scripts/bench_gate.sh`): emitted as
//! BENCH_5.json when `FAST_ESRNN_BENCH_JSON=<path>` is set (and
//! BENCH_8.json via `FAST_ESRNN_BENCH8_JSON=<path>`, BENCH_9.json via
//! `FAST_ESRNN_BENCH9_JSON=<path>`, BENCH_10.json via
//! `FAST_ESRNN_BENCH10_JSON=<path>`); the gate fails when the
//! keep-alive speedup drops below the committed floor
//! (`benches/bench5_baseline.json`), sharding blows up tail latency,
//! scraping costs more than `benches/bench8_baseline.json` allows,
//! hedging stops rescuing the tail (`benches/bench9_baseline.json`),
//! or the observe mix inflates the stateful read p95 past
//! `benches/bench10_baseline.json`.
//!
//! Env:
//!   FAST_ESRNN_QUICK=1         — CI mode: fewer requests
//!   FAST_ESRNN_BENCH_JSON=p    — write the BENCH_5 summary JSON to p
//!   FAST_ESRNN_BENCH8_JSON=p   — write the BENCH_8 summary JSON to p
//!   FAST_ESRNN_BENCH9_JSON=p   — write the BENCH_9 summary JSON to p
//!   FAST_ESRNN_BENCH10_JSON=p  — write the BENCH_10 summary JSON to p
//!
//! Run with: `cargo bench --bench http_throughput`

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use fast_esrnn::config::{Category, Frequency};
use fast_esrnn::coordinator::ModelState;
use fast_esrnn::forecast::{http, ForecastRequest, ForecastResponse,
                           HttpClient, HttpOptions, HttpServer,
                           ResponseReceiver, ServiceOptions, ServiceStats,
                           ServingStack, ShardClient, ShardHealth,
                           ShardedStack};
use fast_esrnn::runtime::NativeBackend;
use fast_esrnn::telemetry::registry::Registry;
use fast_esrnn::util::json::Json;

const FREQ: Frequency = Frequency::Quarterly;
const CLIENTS: usize = 4;
/// BENCH_10: stateful series owned by each client thread.
const B10_SERIES: usize = 8;

fn fresh_state() -> ModelState {
    let backend = NativeBackend::new();
    ModelState::init(&backend, FREQ.name(), 42).unwrap()
}

/// A positive synthetic history long enough for the quarterly C=72 cut
/// (weights are untrained — throughput does not depend on accuracy).
fn forecast_body(id: &str) -> String {
    let values: Vec<f32> = (0..80)
        .map(|i| 100.0 + i as f32 * 0.5 + (i % 4) as f32 * 3.0)
        .collect();
    Json::obj(vec![
        ("id", Json::str(id)),
        ("values", Json::arr_f32(&values)),
    ])
    .to_string()
}

/// Build a server over `shards` stacks × `workers` pool threads each.
fn start_server(shards: usize, workers: usize)
                -> anyhow::Result<(HttpServer, Arc<ShardedStack>)> {
    let sharded = ShardedStack::new();
    for s in 0..shards {
        let mut stack = ServingStack::new();
        stack.start_pool_native(FREQ, fresh_state(), ServiceOptions {
            workers,
            batch_window: Duration::from_millis(1),
            max_batch: 8,
            queue_limit: 0, // the bench measures throughput, not shedding
            ..Default::default()
        })?;
        sharded.add_shard(&format!("shard-{s}"), stack)?;
    }
    let sharded = Arc::new(sharded);
    let server = HttpServer::start_with(
        Arc::clone(&sharded),
        "127.0.0.1:0",
        HttpOptions {
            conn_workers: 8,
            accept_backlog: 256,
            ..Default::default()
        },
    )?;
    Ok((server, sharded))
}

/// A [`ShardClient`] that serves correctly but late: every forecast
/// pays an injected delay before the real in-process stack answers.
/// The ring cannot tell it from a remote replica with a sick disk —
/// which is exactly the failure mode hedged reads exist for.
struct DelayedClient {
    inner: Arc<ServingStack>,
    delay: Duration,
}

impl ShardClient for DelayedClient {
    fn forecast(&self, freq: Frequency, req: ForecastRequest)
                -> anyhow::Result<ForecastResponse> {
        std::thread::sleep(self.delay);
        self.inner.forecast(freq, req)
    }

    fn submit(&self, freq: Frequency, req: ForecastRequest)
              -> anyhow::Result<ResponseReceiver> {
        self.inner.submit(freq, req)
    }

    fn stats_snapshot(&self)
                      -> anyhow::Result<BTreeMap<Frequency, ServiceStats>> {
        Ok(self.inner.stats_all())
    }

    fn reload(&self, freq: Frequency, state: ModelState)
              -> anyhow::Result<u64> {
        self.inner.reload(freq, state)
    }

    fn reload_checkpoint(&self, freq: Frequency, path: &Path)
                         -> anyhow::Result<u64> {
        self.inner.reload_checkpoint(freq, path)
    }

    fn generation(&self, freq: Frequency) -> anyhow::Result<u64> {
        self.inner.generation(freq)
    }

    fn frequencies(&self) -> Vec<Frequency> {
        self.inner.frequencies()
    }

    fn required_length(&self, freq: Frequency) -> anyhow::Result<usize> {
        self.inner.required_length(freq)
    }

    fn healthz(&self) -> anyhow::Result<()> {
        Ok(())
    }

    fn health(&self) -> ShardHealth {
        ShardHealth {
            kind: "local",
            addr: None,
            healthy: true,
            probe_failures: 0,
            ejections: 0,
        }
    }

    fn bind_metrics(&self, reg: &Registry, shard: &str) {
        self.inner.bind_metrics(reg, shard);
    }
}

/// BENCH_9 topology: two healthy in-process shards plus one shard that
/// answers every forecast `delay` late.
fn start_slow_replica_ring(delay: Duration)
                           -> anyhow::Result<Arc<ShardedStack>> {
    let opts = ServiceOptions {
        workers: 1,
        batch_window: Duration::from_millis(1),
        max_batch: 8,
        queue_limit: 0,
        ..Default::default()
    };
    let sharded = ShardedStack::new();
    for s in 0..2 {
        let mut stack = ServingStack::new();
        stack.start_pool_native(FREQ, fresh_state(), opts.clone())?;
        sharded.add_shard(&format!("fast-{s}"), stack)?;
    }
    let mut slow = ServingStack::new();
    slow.start_pool_native(FREQ, fresh_state(), opts)?;
    sharded.add_shard_client(
        "slow",
        Arc::new(DelayedClient { inner: Arc::new(slow), delay }))?;
    Ok(Arc::new(sharded))
}

fn bench9_request(id: &str) -> ForecastRequest {
    let values: Vec<f32> = (0..80)
        .map(|i| 100.0 + i as f32 * 0.5 + (i % 4) as f32 * 3.0)
        .collect();
    ForecastRequest {
        id: id.to_string(),
        values,
        category: Category::Other,
    }
}

/// Sequential in-process load over distinct ids; returns
/// (rps, p50, p95, p99) in seconds.
fn run_ring_load(sharded: &ShardedStack, n: usize)
                 -> anyhow::Result<(f64, f64, f64, f64)> {
    let t0 = Instant::now();
    let mut lat = Vec::with_capacity(n);
    for i in 0..n {
        let t = Instant::now();
        sharded.forecast(FREQ, bench9_request(&format!("b9-{i}")))?;
        lat.push(t.elapsed().as_secs_f64());
    }
    let secs = t0.elapsed().as_secs_f64();
    lat.sort_by(|a, b| a.total_cmp(b));
    let q = |p: usize| lat[(lat.len() * p / 100).min(lat.len() - 1)];
    Ok((n as f64 / secs, q(50), q(95), q(99)))
}

/// `CLIENTS` threads × `per` requests; returns (req/s, p95 secs).
/// `keep_alive` picks one persistent connection per client vs a fresh
/// connection per request; `forecast` picks `POST /v1/forecast` (wire
/// + compute) vs `GET /v1/healthz` (pure wire).
fn run_load(addr: &str, keep_alive: bool, per: usize,
            forecast: bool) -> (f64, f64) {
    let t0 = Instant::now();
    let mut joins = Vec::with_capacity(CLIENTS);
    for c in 0..CLIENTS {
        let addr = addr.to_string();
        joins.push(std::thread::spawn(move || {
            let mut lat = Vec::with_capacity(per);
            let mut client = keep_alive
                .then(|| HttpClient::connect(&addr).unwrap());
            for i in 0..per {
                let body =
                    forecast.then(|| forecast_body(&format!("c{c}-r{i}")));
                let (method, path) = if forecast {
                    ("POST", "/v1/forecast")
                } else {
                    ("GET", "/v1/healthz")
                };
                let t = Instant::now();
                let code = match &mut client {
                    Some(cl) => cl
                        .request(method, path, body.as_deref())
                        .unwrap()
                        .code,
                    None => http::http_request(&addr, method, path,
                                               body.as_deref())
                        .unwrap()
                        .0,
                };
                lat.push(t.elapsed().as_secs_f64());
                assert_eq!(code, 200, "bench request failed");
            }
            lat
        }));
    }
    let mut lat: Vec<f64> = Vec::with_capacity(CLIENTS * per);
    for j in joins {
        lat.extend(j.join().expect("client thread panicked"));
    }
    let secs = t0.elapsed().as_secs_f64();
    lat.sort_by(|a, b| a.total_cmp(b));
    let p95 = lat[(lat.len() * 95 / 100).min(lat.len() - 1)];
    ((CLIENTS * per) as f64 / secs, p95)
}

/// Seed every BENCH_10 series with a first observe batch: the seed is
/// write-path work too, but the rings must exist before the read
/// phases can forecast.
fn seed_series(addr: &str, tag: &str) {
    let mut client = HttpClient::connect(addr).unwrap();
    let vals: Vec<f32> =
        (0..16).map(|i| 100.0 + (i % 4) as f32 * 3.0).collect();
    let body =
        Json::obj(vec![("values", Json::arr_f32(&vals))]).to_string();
    for c in 0..CLIENTS {
        for s in 0..B10_SERIES {
            let reply = client
                .request("POST",
                         &format!("/v1/series/b10-{tag}-{c}-{s}/observe"),
                         Some(&body))
                .unwrap();
            assert_eq!(reply.code, 200, "seed observe failed: {}",
                       reply.body);
        }
    }
}

/// BENCH_10 load over the stateful series routes: `CLIENTS` threads ×
/// `per` ops, each thread cycling through its own `B10_SERIES`
/// pre-seeded series. `observe_every == 0` is a pure forecast-read
/// phase; `k > 0` makes every k-th op a `POST .../observe` batch
/// (`k == 1` → all writes, `k == 2` → the 50% read/write mix).
/// Returns (ops/s, observes issued, forecast p95 secs — 0.0 when the
/// phase had no reads).
fn run_series_load(addr: &str, tag: &str, per: usize,
                   observe_every: usize) -> (f64, u64, f64) {
    let t0 = Instant::now();
    let mut joins = Vec::with_capacity(CLIENTS);
    for c in 0..CLIENTS {
        let addr = addr.to_string();
        let tag = tag.to_string();
        joins.push(std::thread::spawn(move || {
            let mut client = HttpClient::connect(&addr).unwrap();
            let mut lat = Vec::with_capacity(per);
            let mut observes = 0u64;
            for i in 0..per {
                let id = format!("b10-{tag}-{c}-{}", i % B10_SERIES);
                if observe_every > 0 && i % observe_every == 0 {
                    let vals: Vec<f32> = (0..4)
                        .map(|k| 100.0 + ((i + k) % 4) as f32 * 3.0)
                        .collect();
                    let body =
                        Json::obj(vec![("values", Json::arr_f32(&vals))])
                            .to_string();
                    let reply = client
                        .request("POST",
                                 &format!("/v1/series/{id}/observe"),
                                 Some(&body))
                        .unwrap();
                    assert_eq!(reply.code, 200, "observe failed: {}",
                               reply.body);
                    observes += 1;
                } else {
                    let t = Instant::now();
                    let reply = client
                        .request("GET",
                                 &format!("/v1/series/{id}/forecast"),
                                 None)
                        .unwrap();
                    lat.push(t.elapsed().as_secs_f64());
                    assert_eq!(reply.code, 200,
                               "stateful forecast failed: {}", reply.body);
                }
            }
            (lat, observes)
        }));
    }
    let mut lat: Vec<f64> = Vec::with_capacity(CLIENTS * per);
    let mut observes = 0u64;
    for j in joins {
        let (l, o) = j.join().expect("client thread panicked");
        lat.extend(l);
        observes += o;
    }
    let secs = t0.elapsed().as_secs_f64();
    lat.sort_by(|a, b| a.total_cmp(b));
    let p95 = if lat.is_empty() {
        0.0
    } else {
        lat[(lat.len() * 95 / 100).min(lat.len() - 1)]
    };
    ((CLIENTS * per) as f64 / secs, observes, p95)
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("FAST_ESRNN_QUICK").is_ok();
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let wire_per = if quick { 400 } else { 1500 };
    let fc_per = if quick { 60 } else { 150 };

    // ---- keep-alive vs connection-per-request, one single-shard stack.
    let (server, _stack) = start_server(1, 2)?;
    let addr = server.addr().to_string();

    println!("== wire overhead: GET /v1/healthz, {CLIENTS} clients × \
              {wire_per} ==");
    let (wire_pc_rps, _) = run_load(&addr, false, wire_per, false);
    let (wire_ka_rps, _) = run_load(&addr, true, wire_per, false);
    let wire_speedup = wire_ka_rps / wire_pc_rps;
    println!("{:<22} {:>10.0} req/s", "conn-per-request", wire_pc_rps);
    println!("{:<22} {:>10.0} req/s", "keep-alive", wire_ka_rps);
    println!("keep-alive speedup: {wire_speedup:.2}x\n");

    println!("== forecast: POST /v1/forecast, {CLIENTS} clients × \
              {fc_per} ==");
    let (fc_pc_rps, _) = run_load(&addr, false, fc_per, true);
    let (fc_ka_rps, _) = run_load(&addr, true, fc_per, true);
    let fc_speedup = fc_ka_rps / fc_pc_rps;
    println!("{:<22} {:>10.0} req/s", "conn-per-request", fc_pc_rps);
    println!("{:<22} {:>10.0} req/s", "keep-alive", fc_ka_rps);
    println!("keep-alive speedup: {fc_speedup:.2}x\n");
    drop(server);

    // ---- sharded vs single stack, same total worker budget (4).
    println!("== sharding: 1×4 workers vs 4×1, keep-alive, {CLIENTS} \
              clients × {fc_per} ==");
    let (server, _stack) = start_server(1, 4)?;
    let addr = server.addr().to_string();
    let (single_rps, single_p95) = run_load(&addr, true, fc_per, true);
    drop(server);
    let (server, _stack) = start_server(4, 1)?;
    let addr = server.addr().to_string();
    let (sharded_rps, sharded_p95) = run_load(&addr, true, fc_per, true);
    drop(server);
    let p95_ratio = sharded_p95 / single_p95.max(1e-9);
    println!("{:<22} {:>10.0} req/s   p95 {:>8.2}ms", "single 1×4",
             single_rps, single_p95 * 1e3);
    println!("{:<22} {:>10.0} req/s   p95 {:>8.2}ms", "sharded 4×1",
             sharded_rps, sharded_p95 * 1e3);
    println!("sharded/single p95 ratio: {p95_ratio:.2}\n");

    if let Ok(path) = std::env::var("FAST_ESRNN_BENCH_JSON") {
        let mode = |pc: f64, ka: f64, n: usize| {
            Json::obj(vec![
                ("n_requests", Json::num(n as f64)),
                ("per_conn_rps", Json::num(pc)),
                ("keepalive_rps", Json::num(ka)),
                ("keepalive_speedup", Json::num(ka / pc)),
            ])
        };
        let stack_row = |shards: usize, workers: usize, rps: f64,
                         p95: f64| {
            Json::obj(vec![
                ("shards", Json::num(shards as f64)),
                ("workers", Json::num((shards * workers) as f64)),
                ("rps", Json::num(rps)),
                ("p95_ms", Json::num(p95 * 1e3)),
            ])
        };
        let doc = Json::obj(vec![
            ("bench", Json::str("http_throughput")),
            ("quick", Json::Bool(quick)),
            ("threads", Json::num(threads as f64)),
            ("wire", mode(wire_pc_rps, wire_ka_rps, CLIENTS * wire_per)),
            ("forecast", mode(fc_pc_rps, fc_ka_rps, CLIENTS * fc_per)),
            ("single", stack_row(1, 4, single_rps, single_p95)),
            ("sharded", stack_row(4, 1, sharded_rps, sharded_p95)),
            ("sharded_p95_ratio", Json::num(p95_ratio)),
        ]);
        std::fs::write(&path, format!("{doc}\n"))?;
        println!("wrote {path}");
    }

    // ---- BENCH_8: /v1/metrics scrape overhead under forecast load.
    println!("== metrics scrape overhead: POST /v1/forecast, {CLIENTS} \
              clients × {fc_per}, ± 10 Hz /v1/metrics scraper ==");
    let (server, _stack) = start_server(2, 1)?;
    let addr = server.addr().to_string();
    let (base_rps, base_p95) = run_load(&addr, true, fc_per, true);
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let scraper = {
        let addr = addr.clone();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut client = HttpClient::connect(&addr).unwrap();
            let mut scrapes = 0u64;
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                let reply =
                    client.request("GET", "/v1/metrics", None).unwrap();
                assert_eq!(reply.code, 200, "scrape failed mid-bench");
                scrapes += 1;
                std::thread::sleep(Duration::from_millis(100));
            }
            scrapes
        })
    };
    let (scr_rps, scr_p95) = run_load(&addr, true, fc_per, true);
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let scrapes = scraper.join().expect("scraper thread panicked");
    drop(server);
    let scrape_overhead = scr_p95 / base_p95.max(1e-9);
    println!("{:<22} {:>10.0} req/s   p95 {:>8.2}ms", "no scraper",
             base_rps, base_p95 * 1e3);
    println!("{:<22} {:>10.0} req/s   p95 {:>8.2}ms   ({scrapes} scrapes)",
             "10 Hz scraper", scr_rps, scr_p95 * 1e3);
    println!("scrape p95 overhead ratio: {scrape_overhead:.2}\n");

    if let Ok(path) = std::env::var("FAST_ESRNN_BENCH8_JSON") {
        let doc = Json::obj(vec![
            ("bench", Json::str("metrics_scrape_overhead")),
            ("quick", Json::Bool(quick)),
            ("threads", Json::num(threads as f64)),
            ("n_requests", Json::num((CLIENTS * fc_per) as f64)),
            ("baseline", Json::obj(vec![
                ("rps", Json::num(base_rps)),
                ("p95_ms", Json::num(base_p95 * 1e3)),
            ])),
            ("scraped", Json::obj(vec![
                ("rps", Json::num(scr_rps)),
                ("p95_ms", Json::num(scr_p95 * 1e3)),
                ("scrapes", Json::num(scrapes as f64)),
            ])),
            ("p95_overhead_ratio", Json::num(scrape_overhead)),
        ]);
        std::fs::write(&path, format!("{doc}\n"))?;
        println!("wrote {path}");
    }

    // ---- BENCH_9: hedged vs unhedged p99 with one 50 ms-slow replica.
    let b9_n = if quick { 300 } else { 1000 };
    let delay = Duration::from_millis(50);
    println!("== hedged reads: 3-shard ring, one replica +{}ms, \
              {b9_n} sequential requests ==",
             delay.as_millis());

    // Unhedged (R = 1): keys owned by the slow shard pay the full
    // delay, and at ~1/3 ownership the delay IS the p99.
    let ring = start_slow_replica_ring(delay)?;
    ring.set_replicas(1);
    let (un_rps, un_p50, un_p95, un_p99) = run_ring_load(&ring, b9_n)?;
    drop(ring);

    // Hedged (R = 2) on a fresh ring (fresh hedge clock — the unhedged
    // phase must not teach the timer that 50 ms is normal). Warm the
    // clock with healthy-primary traffic first so the rolling p95
    // reflects the healthy fleet, exactly as it would in production
    // where slow replicas are the exception.
    let ring = start_slow_replica_ring(delay)?;
    ring.set_replicas(2);
    let mut warmed = 0usize;
    let mut probe = 0usize;
    while warmed < 64 {
        let id = format!("warm-{probe}");
        probe += 1;
        if ring.shard_for(&id)? != "slow" {
            ring.forecast(FREQ, bench9_request(&id))?;
            warmed += 1;
        }
    }
    let (he_rps, he_p50, he_p95, he_p99) = run_ring_load(&ring, b9_n)?;
    let hedges = ring.hedges();
    let hedge_wins = ring.hedge_wins();
    drop(ring);

    let hedge_speedup = un_p99 / he_p99.max(1e-9);
    println!("{:<22} {:>10.0} req/s   p50 {:>7.2}ms p95 {:>7.2}ms \
              p99 {:>7.2}ms",
             "unhedged (R=1)", un_rps, un_p50 * 1e3, un_p95 * 1e3,
             un_p99 * 1e3);
    println!("{:<22} {:>10.0} req/s   p50 {:>7.2}ms p95 {:>7.2}ms \
              p99 {:>7.2}ms   ({hedges} hedges, {hedge_wins} wins)",
             "hedged (R=2)", he_rps, he_p50 * 1e3, he_p95 * 1e3,
             he_p99 * 1e3);
    println!("hedged p99 speedup: {hedge_speedup:.2}x\n");

    if let Ok(path) = std::env::var("FAST_ESRNN_BENCH9_JSON") {
        let row = |rps: f64, p50: f64, p95: f64, p99: f64| {
            Json::obj(vec![
                ("rps", Json::num(rps)),
                ("p50_ms", Json::num(p50 * 1e3)),
                ("p95_ms", Json::num(p95 * 1e3)),
                ("p99_ms", Json::num(p99 * 1e3)),
            ])
        };
        let hedged = match row(he_rps, he_p50, he_p95, he_p99) {
            Json::Obj(mut m) => {
                m.insert("hedges".into(), Json::num(hedges as f64));
                m.insert("hedge_wins".into(), Json::num(hedge_wins as f64));
                Json::Obj(m)
            }
            other => other,
        };
        let doc = Json::obj(vec![
            ("bench", Json::str("hedged_reads")),
            ("quick", Json::Bool(quick)),
            ("threads", Json::num(threads as f64)),
            ("n_requests", Json::num(b9_n as f64)),
            ("delay_ms", Json::num(delay.as_millis() as f64)),
            ("unhedged", row(un_rps, un_p50, un_p95, un_p99)),
            ("hedged", hedged),
            ("hedge_p99_speedup", Json::num(hedge_speedup)),
        ]);
        std::fs::write(&path, format!("{doc}\n"))?;
        println!("wrote {path}");
    }

    // ---- BENCH_10: stateful series routes — observe throughput, and
    // what a 50% write mix does to the forecast-read tail. Observes
    // bypass the batching queue (µs-scale ES updates) but invalidate
    // the per-series forecast cache, so mixed reads recompute where
    // pure reads hit the cache.
    let b10_per = if quick { 300 } else { 1200 };
    println!("== stateful series routes: {CLIENTS} clients × {b10_per} \
              ops over {B10_SERIES} series each ==");
    let (server, _stack) = start_server(2, 1)?;
    let addr = server.addr().to_string();
    seed_series(&addr, "s");
    let (obs_rps, obs_n, _) = run_series_load(&addr, "s", b10_per, 1);
    let (pure_rps, _, pure_p95) = run_series_load(&addr, "s", b10_per, 0);
    let (mix_rps, mix_obs, mix_p95) =
        run_series_load(&addr, "s", b10_per, 2);
    drop(server);
    let mixed_ratio = mix_p95 / pure_p95.max(1e-9);
    let observe_rps_ratio = obs_rps / pure_rps.max(1e-9);
    println!("{:<22} {:>10.0} obs/s", "observe (all writes)", obs_rps);
    println!("{:<22} {:>10.0} req/s   p95 {:>8.2}ms",
             "forecast (pure reads)", pure_rps, pure_p95 * 1e3);
    println!("{:<22} {:>10.0} ops/s   p95 {:>8.2}ms   ({mix_obs} \
              observes)",
             "forecast (50% mix)", mix_rps, mix_p95 * 1e3);
    println!("mixed/pure read p95 ratio: {mixed_ratio:.2}   \
              observe/read rps ratio: {observe_rps_ratio:.2}\n");

    if let Ok(path) = std::env::var("FAST_ESRNN_BENCH10_JSON") {
        let doc = Json::obj(vec![
            ("bench", Json::str("stateful_series_routes")),
            ("quick", Json::Bool(quick)),
            ("threads", Json::num(threads as f64)),
            ("series", Json::num((CLIENTS * B10_SERIES) as f64)),
            ("observe", Json::obj(vec![
                ("ops", Json::num(obs_n as f64)),
                ("rps", Json::num(obs_rps)),
            ])),
            ("forecast_pure", Json::obj(vec![
                ("ops", Json::num((CLIENTS * b10_per) as f64)),
                ("rps", Json::num(pure_rps)),
                ("p95_ms", Json::num(pure_p95 * 1e3)),
            ])),
            ("forecast_mixed", Json::obj(vec![
                ("ops", Json::num((CLIENTS * b10_per) as f64)),
                ("observes", Json::num(mix_obs as f64)),
                ("rps", Json::num(mix_rps)),
                ("p95_ms", Json::num(mix_p95 * 1e3)),
            ])),
            ("mixed_p95_ratio", Json::num(mixed_ratio)),
            ("observe_rps_ratio", Json::num(observe_rps_ratio)),
        ]);
        std::fs::write(&path, format!("{doc}\n"))?;
        println!("wrote {path}");
    }
    Ok(())
}
