//! Table 4 reproduction: test-holdout sMAPE by frequency for ES-RNN vs the
//! M4 Comb benchmark (the quoted Smyl / Hyndman rows are printed from the
//! paper for context — neither is reproducible without the original M4
//! testbed).
//!
//! Run with: `cargo bench --bench table4_accuracy`
//! Env: FAST_ESRNN_SCALE (default 100), FAST_ESRNN_EPOCHS (default 10).

use fast_esrnn::baselines::{Comb, Forecaster};
use fast_esrnn::config::{NetworkConfig, TrainConfig, MODELED_FREQS};
use fast_esrnn::coordinator::{EvalSplit, Trainer};
use fast_esrnn::data::{generate, split_corpus, GenOptions};
use fast_esrnn::metrics::smape;
use fast_esrnn::runtime::{default_backend, Backend};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let scale = env_usize("FAST_ESRNN_SCALE", 100);
    let epochs = env_usize("FAST_ESRNN_EPOCHS", 10);
    let backend = default_backend()?;
    let corpus = generate(&GenOptions { scale, ..Default::default() })?;
    println!("corpus 1/{scale} of Table 2 | {epochs} epochs | backend {}\n",
             backend.platform());

    let mut es_row = Vec::new();
    let mut comb_row = Vec::new();
    for freq in MODELED_FREQS {
        let net = NetworkConfig::for_freq(freq)?;
        let tc = TrainConfig {
            epochs,
            batch_size: 64,
            ..Default::default()
        };
        let mut trainer = Trainer::new(backend.as_ref(), freq, &corpus, tc)?;
        eprintln!("[table4] training {} on {} series…", freq.name(),
                  trainer.series_count());
        trainer.train(false)?;
        let test = trainer.evaluate(EvalSplit::Test)?;
        es_row.push(test.smape);

        let set = split_corpus(&corpus, &net)?;
        let mut acc = 0.0;
        for sp in &set.series {
            let fc = Comb.forecast(&sp.refit, net.seasonality, net.horizon);
            acc += smape(&fc, &sp.test);
        }
        comb_row.push(acc / set.series.len() as f64);
    }

    let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    println!("== Table 4: sMAPE by frequency (our corpus) ==");
    println!("{:<22} {:>8} {:>10} {:>8} {:>9} {:>9}", "model", "Yearly",
             "Quarterly", "Monthly", "Average", "% impr");
    println!("{:<22} {:>8.3} {:>10.3} {:>8.3} {:>9.3} {:>9}",
             "Comb (benchmark)", comb_row[0], comb_row[1], comb_row[2],
             avg(&comb_row), "-");
    let impr = 100.0 * (avg(&comb_row) - avg(&es_row)) / avg(&comb_row);
    println!("{:<22} {:>8.3} {:>10.3} {:>8.3} {:>9.3} {:>8.1}%",
             "ES-RNN (ours)", es_row[0], es_row[1], es_row[2], avg(&es_row),
             impr);

    println!("\npaper Table 4 (real M4 data, for reference):");
    println!("{:<22} {:>8} {:>10} {:>8} {:>9} {:>9}", "", "Yearly",
             "Quarterly", "Monthly", "Average", "% impr");
    println!("{:<22} {:>8} {:>10} {:>8} {:>9} {:>9}", "Benchmark (Comb)",
             "14.848", "10.175", "13.434", "12.95", "-");
    println!("{:<22} {:>8} {:>10} {:>8} {:>9} {:>9}", "Smyl et al. (2018)",
             "13.176", "9.679", "12.126", "11.76", "9.2%");
    println!("{:<22} {:>8} {:>10} {:>8} {:>9} {:>9}", "Hyndman (2018)",
             "13.528", "9.733", "12.639", "11.86", "8.4%");
    println!("{:<22} {:>8} {:>10} {:>8} {:>9} {:>9}", "Redd et al. (GPU)",
             "14.42", "10.09", "10.81", "11.50", "11.2%");
    println!("\nreproduced claim: ES-RNN beats the Comb benchmark on average \
              (shape, not absolute values — synthetic corpus).");
    Ok(())
}
